//! The `Threads` knob and the persistent [`KernelPool`] behind it: one
//! explicit worker-thread budget threaded through the dense kernel
//! layer, `DensePhases`, the experiment harness, and the CLI
//! (`--threads`) — executed by a process-wide pool of parked workers
//! instead of per-call `std::thread::scope` spawning.
//!
//! Every parallel kernel partitions *output columns* (or rows, for the
//! sparse panel products) across chunks, so each output element is
//! produced by exactly one executor with the same sequential reduction
//! order regardless of the worker count — results are bitwise identical
//! for `Threads(1)` and `Threads(n)`, and identical no matter which
//! pool worker (or the caller itself) happens to claim a chunk.
//!
//! This file is the only place in `rust/src` allowed to spawn raw
//! threads (`detlint` rule `thread-spawn`): the pool workers are
//! created here once, and [`run_scoped_baseline`] keeps the old
//! spawn-per-call path alive *for benchmarks only* so the dispatch
//! overhead claim stays measurable.  It is also the crate's only home
//! of `unsafe`: the lifetime erasure that lets a persistent pool run
//! borrowed-closure jobs, sound because [`KernelPool::run`] blocks
//! until every chunk has checked in (see the SAFETY comments).

use crate::linalg::kernel_core::{ChunkRunner, DispatchCore};
use crate::sync::{Arc, Mutex, OnceLock, OnceSlot};
use std::cell::Cell;

/// Worker-thread budget for the dense kernels.
///
/// * `Threads(0)` (= [`Threads::AUTO`]) resolves to the machine's
///   available parallelism, capped at [`MAX_AUTO_THREADS`].
/// * `Threads(1)` forces the sequential path.
/// * `Threads(n)` uses at most `n` workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Threads(pub usize);

/// Cap on auto-detected parallelism (the kernels are memory-bound well
/// before this point on typical hardware).
pub const MAX_AUTO_THREADS: usize = 16;

/// Minimum flop count of a kernel invocation before it fans out across
/// the kernel pool; below this the per-call dispatch cost dominates.
///
/// Recalibrated for the pool era.  The spawn-per-call path this
/// replaced cost tens of µs per invocation (thread creation + join —
/// see `dispatch_scoped_smallk` in `BENCH_linalg.json`, measured via
/// [`run_scoped_baseline`]), which justified the old `1 << 22` gate:
/// a kernel needed milliseconds of work before fan-out paid.  Waking
/// parked workers is a mutex/condvar handoff (`dispatch_pool_smallk`,
/// single-digit µs), so the break-even shrinks by roughly the same
/// factor: `1 << 19` flops is ~100 µs of sequential kernel work at the
/// few-Gflop/s these scalar kernels sustain, comfortably above the
/// handoff cost while letting the paper's small-k regime (k ≤ 96)
/// fan out where the old gate kept it sequential.
pub const PAR_MIN_FLOPS: usize = 1 << 19;

/// Machine parallelism, detected once per process ([`OnceSlot`]-cached
/// so the kernel hot path never re-queries the OS).
fn detected_parallelism() -> usize {
    static DETECTED: OnceSlot<usize> = OnceSlot::new();
    DETECTED.get_or_init(|| {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    })
}

/// CPU vector-capability tiers of the packed GEMM micro-kernel rungs
/// (`GemmKernel::{PackedSimd, PackedFma}` in `linalg::blas`).  Ordered:
/// a higher level implies every capability of the lower ones, so rungs
/// clamp a requested level with `min` against the detected one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// No runtime-detected vector extensions beyond the compile-time
    /// baseline; the packed scalar micro-kernel runs everywhere.
    Scalar,
    /// AVX2: 4-lane f64 vectors with separate mul/add rounding — the
    /// *bitwise* SIMD rung.
    Avx2,
    /// AVX2 + FMA: fused multiply-add, one rounding per update — faster
    /// but **not** bitwise against the scalar oracle; opt-in only.
    Avx2Fma,
}

/// The machine's SIMD capability, detected once per process — the same
/// [`OnceSlot`] pattern as [`detected_parallelism`], because the
/// per-chunk `Auto` routing in `blas::run_gemm_chunk` must not re-run
/// feature detection on the kernel hot path.
///
/// `GREST_SIMD=off` (or `scalar`) forces [`SimdLevel::Scalar`] — the CI
/// leg proving the ladder's results don't depend on the vector units —
/// and `GREST_SIMD=avx2` caps detection below FMA.  The variable is read
/// once, at first detection; tests that need a specific level pass it
/// explicitly (`gemm_simd::gemm_acc_cols_simd_level`) rather than racing
/// this cache.
pub fn simd_level() -> SimdLevel {
    static LEVEL: OnceSlot<SimdLevel> = OnceSlot::new();
    LEVEL.get_or_init(|| {
        let detected = detect_simd_level();
        match std::env::var("GREST_SIMD").ok().as_deref() {
            Some("off") | Some("scalar") => SimdLevel::Scalar,
            Some("avx2") => detected.min(SimdLevel::Avx2),
            _ => detected,
        }
    })
}

/// Raw cpuid-backed detection ignoring the env override.  Uses only the
/// `is_x86_feature_detected!` macro — `std::arch` intrinsics themselves
/// are confined to `linalg/gemm_simd.rs` (detlint rule `raw-intrinsics`).
#[cfg(target_arch = "x86_64")]
fn detect_simd_level() -> SimdLevel {
    if is_x86_feature_detected!("avx2") {
        if is_x86_feature_detected!("fma") {
            SimdLevel::Avx2Fma
        } else {
            SimdLevel::Avx2
        }
    } else {
        SimdLevel::Scalar
    }
}

/// Non-x86_64 targets have no stable-intrinsics rung: everything runs
/// the packed scalar micro-kernel (bitwise identical by construction).
#[cfg(not(target_arch = "x86_64"))]
fn detect_simd_level() -> SimdLevel {
    SimdLevel::Scalar
}

impl Threads {
    /// Resolve the worker count from the machine.
    pub const AUTO: Threads = Threads(0);
    /// Always sequential.
    pub const SINGLE: Threads = Threads(1);

    /// Concrete worker count this budget resolves to.
    pub fn resolve(self) -> usize {
        if self.0 != 0 {
            return self.0;
        }
        detected_parallelism().min(MAX_AUTO_THREADS)
    }

    /// Worker count for a kernel performing `flops` floating-point ops:
    /// 1 below the parallel threshold, the resolved budget above it.
    pub fn for_flops(self, flops: usize) -> usize {
        if flops < PAR_MIN_FLOPS {
            1
        } else {
            self.resolve()
        }
    }
}

impl Default for Threads {
    fn default() -> Threads {
        Threads::AUTO
    }
}

/// Split `cols` output columns into at most `workers` contiguous chunks
/// whose *work* (given by `weight(j)` per column) is roughly balanced.
/// Used by the triangular (syrk-style) kernels where column `j` costs
/// `O(j)`.
pub fn balanced_col_chunks(
    cols: usize,
    workers: usize,
    weight: impl Fn(usize) -> usize,
) -> Vec<(usize, usize)> {
    let workers = workers.max(1).min(cols.max(1));
    if cols == 0 {
        return vec![];
    }
    if workers == 1 {
        return vec![(0, cols)];
    }
    let total: usize = (0..cols).map(&weight).sum::<usize>().max(1);
    let per = total.div_ceil(workers);
    let mut chunks = Vec::with_capacity(workers);
    let mut start = 0;
    let mut acc = 0;
    for j in 0..cols {
        acc += weight(j);
        if acc >= per && j + 1 < cols {
            chunks.push((start, j + 1));
            start = j + 1;
            acc = 0;
        }
    }
    chunks.push((start, cols));
    chunks
}

// ---------------------------------------------------------------------
// the persistent kernel pool

thread_local! {
    /// True while this thread is executing a pool chunk.  A kernel
    /// invoked from inside one (nested parallelism) must not publish to
    /// the pool — the outer call holds the caller gate, so re-entering
    /// would deadlock.  [`KernelPool::run`] checks this flag and runs
    /// nested work inline instead (bitwise-identical, see module docs).
    static IN_POOL_CHUNK: Cell<bool> = const { Cell::new(false) };
}

/// Restores the [`IN_POOL_CHUNK`] flag on drop, so a panicking chunk
/// unwinding through a worker leaves the flag consistent.
struct ChunkFlagGuard {
    prev: bool,
}

impl ChunkFlagGuard {
    fn enter() -> ChunkFlagGuard {
        ChunkFlagGuard { prev: IN_POOL_CHUNK.with(|f| f.replace(true)) }
    }
}

impl Drop for ChunkFlagGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL_CHUNK.with(|f| f.set(prev));
    }
}

/// The borrowed per-call context a published job points at.  Lives on
/// the publisher's stack for the duration of `publish_and_wait`.
struct RunCtx<'a, T, F> {
    /// One slot per chunk; the claimant of chunk `i` takes part `i` by
    /// value.  Exactly-once claiming is the dispatch core's contract;
    /// the mutex makes slot handoff race-free without `unsafe`.
    parts: &'a [Mutex<Option<T>>],
    f: &'a F,
}

/// Type-erased trampoline: recovers the concrete `RunCtx<T, F>` and
/// runs part `chunk` under the re-entrancy flag.
///
/// # Safety
///
/// `ctx` must point to a live `RunCtx<T, F>` whose `parts` bank has at
/// least `chunk + 1` slots.  [`KernelPool::run`] guarantees this: the
/// context outlives every invocation because `publish_and_wait` blocks
/// until all chunks check in, and chunk indices come from the dispatch
/// cursor bounded by the bank length.
unsafe fn run_part<T: Send, F: Fn(T) + Sync>(ctx: *const (), chunk: usize) {
    // SAFETY: per this function's contract, `ctx` points to a live
    // `RunCtx<T, F>` for the duration of the call (the publisher is
    // blocked inside `publish_and_wait` until we check in).
    let ctx = unsafe { &*ctx.cast::<RunCtx<'_, T, F>>() };
    let part = ctx.parts[chunk].lock().take().expect("kernel chunk dispatched twice");
    let _flag = ChunkFlagGuard::enter();
    (ctx.f)(part);
}

/// The lifetime-erased job the pool dispatches: a trampoline fn pointer
/// plus the publisher-stack context it reconstitutes.
#[derive(Clone, Copy)]
struct ErasedJob {
    run: unsafe fn(*const (), usize),
    ctx: *const (),
}

// SAFETY: `ctx` is only dereferenced by `run` (inside `run_chunk`)
// while the publishing thread is blocked in `publish_and_wait`, so the
// pointee — a `RunCtx` of `Sync` shared references (`&[Mutex<Option<T>>]`
// with `T: Send`, `&F` with `F: Sync`) — is live and safe to share with
// the worker threads the job crosses to.
unsafe impl Send for ErasedJob {}

impl ChunkRunner for ErasedJob {
    fn run_chunk(&self, chunk: usize) {
        // SAFETY: `self.ctx`/`self.run` were built as a matching pair by
        // `KernelPool::run` from a context that outlives this call (the
        // publisher blocks until every chunk checks in), satisfying
        // `run_part`'s contract.
        unsafe { (self.run)(self.ctx, chunk) }
    }
}

/// A persistent pool of parked kernel workers.
///
/// One process-wide instance ([`kernel_pool`]) executes every parallel
/// kernel invocation: the caller publishes a chunked work descriptor,
/// participates in running chunks, and returns when all have checked in
/// — a drop-in replacement for the old per-call `std::thread::scope`
/// blocks, minus the ~tens-of-µs spawn/join cost per invocation.
/// Callers are serialized by a gate (one descriptor in flight at a
/// time), which is also what keeps the coordinator's `WorkerPool` and
/// this pool composable: however many tenants step concurrently, at
/// most `workers + 1` kernel threads are ever running.
pub struct KernelPool {
    core: Arc<DispatchCore<ErasedJob>>,
    /// Parked helper threads (the caller is the `+1`th executor).
    workers: usize,
    handles: Mutex<Vec<crate::sync::thread::JoinHandle<()>>>,
    /// Serializes publishers: the dispatch core holds at most one
    /// descriptor, and a second publisher must not overwrite it.
    gate: Mutex<()>,
}

impl KernelPool {
    /// Pool with `workers` parked helper threads (tests; the global
    /// pool sizes itself from the machine).
    pub fn with_workers(workers: usize) -> KernelPool {
        let core = Arc::new(DispatchCore::new());
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let core: Arc<DispatchCore<ErasedJob>> = Arc::clone(&core);
            handles.push(crate::sync::thread::spawn_named(
                &format!("grest-kernel-{i}"),
                move || core.worker_loop(),
            ));
        }
        KernelPool { core, workers, handles: Mutex::new(handles), gate: Mutex::new(()) }
    }

    /// Run `f` once per part, distributing parts across the pool's
    /// workers and the calling thread.  Blocks until every part has
    /// been processed — the closure may therefore borrow freely from
    /// the caller's stack, exactly like `std::thread::scope`.
    ///
    /// Each part is processed by exactly one executor; with parts that
    /// partition the output (the kernel convention), results are
    /// bitwise identical to running `f` over `parts` sequentially.
    /// Nested calls from inside a chunk run inline (no deadlock, same
    /// results); so do single-part and zero-worker calls.
    pub fn run<T: Send, F: Fn(T) + Sync>(&self, parts: Vec<T>, f: F) {
        if parts.len() <= 1 || self.workers == 0 || IN_POOL_CHUNK.with(|c| c.get()) {
            for p in parts {
                f(p);
            }
            return;
        }
        let n = parts.len();
        let bank: Vec<Mutex<Option<T>>> = parts.into_iter().map(|p| Mutex::new(Some(p))).collect();
        let ctx = RunCtx { parts: &bank[..], f: &f };
        let job = ErasedJob {
            run: run_part::<T, F>,
            ctx: (&ctx as *const RunCtx<'_, T, F>).cast(),
        };
        let _gate = self.gate.lock();
        // `publish_and_wait` returns only after all `n` chunks checked
        // in, so `ctx` (and everything it borrows) outlives every
        // dereference of the erased pointer — the SAFETY obligations of
        // `run_part` and `ErasedJob` bottom out here.
        self.core.publish_and_wait(job, n);
    }

    /// Ask the workers to exit and join them (used by tests and `Drop`;
    /// the process-wide pool lives for the program's lifetime).
    fn shutdown(&self) {
        self.core.shutdown();
        for h in self.handles.lock().drain(..) {
            // a worker that panicked mid-chunk already surfaced the
            // panic at its publisher; ignore the secondary join error
            let _ = h.join();
        }
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The process-wide kernel pool, started on first parallel kernel call
/// with one parked worker per detected core (capped at
/// [`MAX_AUTO_THREADS`]) *minus one* — the publishing caller is itself
/// an executor, so total kernel concurrency equals the cap.
pub fn kernel_pool() -> &'static KernelPool {
    static POOL: OnceLock<KernelPool> = OnceLock::new();
    POOL.get_or_init(|| {
        KernelPool::with_workers(detected_parallelism().min(MAX_AUTO_THREADS).saturating_sub(1))
    })
}

/// The pre-pool dispatch path: spawn one scoped thread per part, join
/// them all.  **Benchmark baseline only** — no kernel calls this; it
/// exists so `microbench_linalg` can measure pool dispatch against the
/// spawn-per-call cost it replaced (`dispatch_scoped_smallk` vs
/// `dispatch_pool_smallk` in `BENCH_linalg.json`).
pub fn run_scoped_baseline<T: Send, F: Fn(T) + Sync>(parts: Vec<T>, f: F) {
    std::thread::scope(|s| {
        for p in parts {
            let f = &f;
            s.spawn(move || f(p));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_explicit_and_auto() {
        assert_eq!(Threads(3).resolve(), 3);
        assert!(Threads::AUTO.resolve() >= 1);
        assert!(Threads::AUTO.resolve() <= MAX_AUTO_THREADS);
        assert_eq!(Threads::SINGLE.resolve(), 1);
        // the OnceSlot cache answers consistently across calls
        assert_eq!(Threads::AUTO.resolve(), Threads::AUTO.resolve());
    }

    #[test]
    fn simd_level_is_cached_and_ordered() {
        // the OnceSlot cache answers consistently across calls
        assert_eq!(simd_level(), simd_level());
        // the ordering the clamp in gemm_simd relies on
        assert!(SimdLevel::Scalar < SimdLevel::Avx2);
        assert!(SimdLevel::Avx2 < SimdLevel::Avx2Fma);
        assert_eq!(SimdLevel::Avx2Fma.min(simd_level()), simd_level());
    }

    #[test]
    fn for_flops_thresholds() {
        assert_eq!(Threads(8).for_flops(16), 1);
        assert_eq!(Threads(8).for_flops(PAR_MIN_FLOPS), 8);
    }

    #[test]
    fn chunks_cover_everything_in_order() {
        for &(cols, workers) in &[(0usize, 4usize), (1, 4), (7, 3), (100, 8), (5, 9)] {
            let chunks = balanced_col_chunks(cols, workers, |j| j + 1);
            let mut expect = 0;
            for &(lo, hi) in &chunks {
                assert_eq!(lo, expect);
                assert!(hi > lo);
                expect = hi;
            }
            assert_eq!(expect, cols);
            assert!(chunks.len() <= workers.max(1));
        }
    }

    #[test]
    fn triangular_weights_balance() {
        // with weight j+1 the last chunk must not hold most columns
        let chunks = balanced_col_chunks(64, 4, |j| j + 1);
        assert!(chunks.len() >= 2);
        let (lo, hi) = chunks[chunks.len() - 1];
        assert!(hi - lo < 40, "last chunk too wide: {lo}..{hi}");
    }

    #[test]
    fn pool_runs_every_part_exactly_once() {
        let pool = KernelPool::with_workers(3);
        let n = 23;
        let mut out = vec![0u64; n];
        let parts: Vec<(usize, &mut u64)> = out.iter_mut().enumerate().collect();
        pool.run(parts, |(i, slot)| *slot = (i as u64 + 1) * 7);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64 + 1) * 7, "part {i} ran wrong or not at all");
        }
        // repeated dispatch through the same (persistent) pool
        for round in 0..5u64 {
            let parts: Vec<&mut u64> = out.iter_mut().collect();
            pool.run(parts, |slot| *slot += round);
        }
        assert_eq!(out[0], 7 + 10); // rounds added 0+1+2+3+4
    }

    #[test]
    fn global_pool_matches_sequential() {
        let n = 101;
        let mut a = vec![0.0f64; n];
        let parts: Vec<(usize, &mut f64)> = a.iter_mut().enumerate().collect();
        kernel_pool().run(parts, |(i, slot)| *slot = (i as f64).sqrt());
        for (i, &v) in a.iter().enumerate() {
            assert_eq!(v.to_bits(), (i as f64).sqrt().to_bits());
        }
    }

    #[test]
    fn nested_run_from_inside_a_chunk_completes_inline() {
        // the re-entrancy guard: a kernel invoked from a pool chunk must
        // not publish (the gate is held) — it runs inline instead
        let pool = KernelPool::with_workers(2);
        let outer = 4;
        let inner = 8;
        let mut out = vec![0u32; outer * inner];
        let parts: Vec<(usize, &mut [u32])> = out.chunks_mut(inner).enumerate().collect();
        pool.run(parts, |(oi, block)| {
            let inner_parts: Vec<(usize, &mut u32)> = block.iter_mut().enumerate().collect();
            // would deadlock without the IN_POOL_CHUNK inline path
            pool.run(inner_parts, |(ii, slot)| *slot = (oi * inner + ii) as u32 + 1);
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u32 + 1);
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = KernelPool::with_workers(0);
        let mut out = [0u8; 5];
        let parts: Vec<&mut u8> = out.iter_mut().collect();
        pool.run(parts, |slot| *slot = 9);
        assert_eq!(out, [9; 5]);
    }

    #[test]
    fn chunk_panic_surfaces_at_the_publisher_and_pool_survives() {
        let pool = KernelPool::with_workers(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(vec![0usize, 1, 2, 3], |i| {
                assert!(i != 2, "seeded chunk failure");
            });
        }));
        assert!(caught.is_err(), "the chunk panic must reach the publisher");
        // the descriptor was retired; the pool still dispatches
        let mut out = [0u8; 4];
        let parts: Vec<&mut u8> = out.iter_mut().collect();
        pool.run(parts, |slot| *slot = 3);
        assert_eq!(out, [3; 4]);
    }

    #[test]
    fn scoped_baseline_matches_pool() {
        let n = 17;
        let mut a = vec![0u64; n];
        let parts: Vec<(usize, &mut u64)> = a.iter_mut().enumerate().collect();
        run_scoped_baseline(parts, |(i, slot)| *slot = i as u64 * 3);
        let mut b = vec![0u64; n];
        let parts: Vec<(usize, &mut u64)> = b.iter_mut().enumerate().collect();
        kernel_pool().run(parts, |(i, slot)| *slot = i as u64 * 3);
        assert_eq!(a, b);
    }
}
