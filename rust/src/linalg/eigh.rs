//! Dense symmetric eigensolver: Householder tridiagonalization followed by
//! the implicit-shift QL iteration (EISPACK `tred2`/`tql2` lineage).  This
//! is the "direct eigenvalue solver" of Alg. 2 line 9, applied to the
//! small (K+M)×(K+M) Rayleigh-Ritz matrix.

use crate::linalg::mat::Mat;

/// Result of a symmetric eigendecomposition, eigenvalues ascending.
pub struct EighResult {
    /// Eigenvalues in ascending order.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors as columns, matching `values`.
    pub vectors: Mat,
}

impl EighResult {
    /// Indices of the K leading eigenvalues by |λ| (paper's ordering),
    /// largest magnitude first; exact-|λ| ties break toward the positive
    /// eigenvalue so that ± pairs order deterministically.  NaN
    /// eigenvalues (a degenerate projected matrix T) rank last instead
    /// of panicking the comparator — mirroring the `tasks::centrality`
    /// NaN policy.
    pub fn leading_by_magnitude(&self, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        order_by_magnitude_into(&self.values, k, &mut idx);
        idx
    }

    /// Indices of the K algebraically largest eigenvalues, largest
    /// first; NaN ranks last.
    pub fn leading_by_value(&self, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.values.len()).collect();
        let key = |v: f64| if v.is_nan() { f64::NEG_INFINITY } else { v };
        idx.sort_unstable_by(|&a, &b| {
            key(self.values[b])
                .total_cmp(&key(self.values[a]))
                .then(a.cmp(&b))
        });
        idx.truncate(k);
        idx
    }
}

/// NaN-safe |λ|-descending ordering written into a caller-owned index
/// buffer (the allocation-free core of
/// [`EighResult::leading_by_magnitude`]): largest magnitude first,
/// exact-|λ| ties toward the positive eigenvalue, then by index; NaN
/// entries rank last.
pub fn order_by_magnitude_into(values: &[f64], k: usize, idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..values.len());
    let mag = |v: f64| if v.is_nan() { f64::NEG_INFINITY } else { v.abs() };
    let val = |v: f64| if v.is_nan() { f64::NEG_INFINITY } else { v };
    idx.sort_unstable_by(|&a, &b| {
        mag(values[b])
            .total_cmp(&mag(values[a]))
            .then(val(values[b]).total_cmp(&val(values[a])))
            .then(a.cmp(&b))
    });
    idx.truncate(k);
}

/// Reusable scratch of [`eigh_into`]: the accumulated transform /
/// eigenvector matrix `v`, the eigenvalues `d` (ascending), and the
/// off-diagonal workspace `e`.
pub struct EighWork {
    pub v: Mat,
    pub d: Vec<f64>,
    pub e: Vec<f64>,
}

impl EighWork {
    pub fn new() -> EighWork {
        EighWork { v: Mat::zeros(0, 0), d: Vec::new(), e: Vec::new() }
    }
}

/// Full symmetric eigendecomposition of `a` (upper part referenced).
pub fn eigh(a: &Mat) -> EighResult {
    let mut w = EighWork::new();
    eigh_into(a, &mut w);
    EighResult { values: w.d, vectors: w.v }
}

/// [`eigh`] into reusable scratch: on return `w.v` holds the
/// orthonormal eigenvectors as columns and `w.d` the matching
/// eigenvalues in ascending order.  No allocation once `w` has seen the
/// problem size.
pub fn eigh_into(a: &Mat, w: &mut EighWork) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigh requires a square matrix");
    w.v.copy_from(a);
    w.d.clear();
    w.d.resize(n, 0.0);
    w.e.clear();
    w.e.resize(n, 0.0);
    if n == 0 {
        return;
    }
    tred2(&mut w.v, &mut w.d, &mut w.e);
    tql2(&mut w.v, &mut w.d, &mut w.e);
    // Sort ascending in place (tql2 output is already sorted, but keep
    // the invariant explicit and robust; `<` leaves NaNs in place
    // instead of panicking a comparator).
    for i in 0..n.saturating_sub(1) {
        let mut kmin = i;
        for j in i + 1..n {
            if w.d[j] < w.d[kmin] {
                kmin = j;
            }
        }
        if kmin != i {
            w.d.swap(i, kmin);
            w.v.swap_cols(i, kmin);
        }
    }
}

/// Householder reduction to tridiagonal form (ports EISPACK/JAMA tred2).
/// On exit `v` holds the accumulated orthogonal transform, `d` the
/// diagonal and `e` the sub-diagonal.
fn tred2(v: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    let n = v.rows();
    for j in 0..n {
        d[j] = v.get(n - 1, j);
    }
    for i in (1..n).rev() {
        let mut scale = 0.0;
        let mut h = 0.0;
        for item in d.iter().take(i) {
            scale += item.abs();
        }
        if scale == 0.0 {
            e[i] = d[i - 1];
            for j in 0..i {
                d[j] = v.get(i - 1, j);
                v.set(i, j, 0.0);
                v.set(j, i, 0.0);
            }
        } else {
            for item in d.iter_mut().take(i) {
                *item /= scale;
                h += *item * *item;
            }
            let mut f = d[i - 1];
            let mut g = h.sqrt();
            if f > 0.0 {
                g = -g;
            }
            e[i] = scale * g;
            h -= f * g;
            d[i - 1] = f - g;
            for item in e.iter_mut().take(i) {
                *item = 0.0;
            }
            for j in 0..i {
                f = d[j];
                v.set(j, i, f);
                g = e[j] + v.get(j, j) * f;
                for k in j + 1..i {
                    g += v.get(k, j) * d[k];
                    e[k] += v.get(k, j) * f;
                }
                e[j] = g;
            }
            f = 0.0;
            for j in 0..i {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..i {
                e[j] -= hh * d[j];
            }
            for j in 0..i {
                f = d[j];
                g = e[j];
                for k in j..i {
                    let cur = v.get(k, j);
                    v.set(k, j, cur - (f * e[k] + g * d[k]));
                }
                d[j] = v.get(i - 1, j);
                v.set(i, j, 0.0);
            }
        }
        d[i] = h;
    }
    // Accumulate transformations.
    for i in 0..n - 1 {
        let vii = v.get(i, i);
        v.set(n - 1, i, vii);
        v.set(i, i, 1.0);
        let h = d[i + 1];
        if h != 0.0 {
            for (k, item) in d.iter_mut().enumerate().take(i + 1) {
                *item = v.get(k, i + 1) / h;
            }
            for j in 0..=i {
                let mut g = 0.0;
                for k in 0..=i {
                    g += v.get(k, i + 1) * v.get(k, j);
                }
                for k in 0..=i {
                    let cur = v.get(k, j);
                    v.set(k, j, cur - g * d[k]);
                }
            }
        }
        for k in 0..=i {
            v.set(k, i + 1, 0.0);
        }
    }
    for j in 0..n {
        d[j] = v.get(n - 1, j);
        v.set(n - 1, j, 0.0);
    }
    v.set(n - 1, n - 1, 1.0);
    e[0] = 0.0;
}

/// Implicit-shift QL for a symmetric tridiagonal matrix (ports tql2),
/// accumulating rotations into `v`.
fn tql2(v: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    let n = v.rows();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    let mut f = 0.0;
    let mut tst1: f64 = 0.0;
    let eps = 2.0_f64.powi(-52);
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                assert!(iter < 200, "tql2 failed to converge");
                let g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = p.hypot(1.0);
                if p < 0.0 {
                    r = -r;
                }
                d[l] = e[l] / (p + r);
                d[l + 1] = e[l] * (p + r);
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for item in d.iter_mut().take(n).skip(l + 2) {
                    *item -= h;
                }
                f += h;
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    let g2 = c * e[i];
                    h = c * p;
                    r = p.hypot(e[i]);
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g2;
                    d[i + 1] = h + s * (c * g2 + s * d[i]);
                    for k in 0..n {
                        h = v.get(k, i + 1);
                        let vk = v.get(k, i);
                        v.set(k, i + 1, s * vk + c * h);
                        v.set(k, i, c * vk - s * h);
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }
    // Sort ascending (selection sort, swapping vector columns).
    for i in 0..n.saturating_sub(1) {
        let mut k = i;
        let mut p = d[i];
        for j in i + 1..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d.swap(i, k);
            for r in 0..n {
                let tmp = v.get(r, i);
                v.set(r, i, v.get(r, k));
                v.set(r, k, tmp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::rng::Rng;

    fn rand_sym(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::randn(n, n, rng);
        let mut s = a.clone();
        s.axpy(1.0, &a.t());
        s.scale(0.5);
        s
    }

    fn check_decomposition(a: &Mat, r: &EighResult, tol: f64) {
        let n = a.rows();
        // A v_i = λ_i v_i
        for i in 0..n {
            let av = blas::gemv(a, r.vectors.col(i));
            for k in 0..n {
                assert!(
                    (av[k] - r.values[i] * r.vectors.get(k, i)).abs() < tol,
                    "residual at eigenpair {i}"
                );
            }
        }
        // orthonormal
        let g = r.vectors.t_matmul(&r.vectors);
        let mut eye = Mat::eye(n);
        eye.axpy(-1.0, &g);
        assert!(eye.max_abs() < tol);
        // ascending
        for i in 1..n {
            assert!(r.values[i] >= r.values[i - 1]);
        }
    }

    #[test]
    fn analytic_2x2() {
        let a = Mat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let r = eigh(&a);
        assert!((r.values[0] - 1.0).abs() < 1e-12);
        assert!((r.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::diag(&[3.0, -1.0, 2.0, 0.5]);
        let r = eigh(&a);
        let want = [-1.0, 0.5, 2.0, 3.0];
        for (got, w) in r.values.iter().zip(want.iter()) {
            assert!((got - w).abs() < 1e-12);
        }
    }

    #[test]
    fn random_matrices_various_sizes() {
        let mut rng = Rng::new(10);
        for &n in &[1usize, 2, 3, 5, 10, 33, 64, 128] {
            let a = rand_sym(n, &mut rng);
            let r = eigh(&a);
            check_decomposition(&a, &r, 1e-8);
        }
    }

    #[test]
    fn repeated_eigenvalues() {
        // I + rank-1: eigenvalues {1 (n-1 times), 1 + n}
        let n = 12;
        let ones = Mat::from_fn(n, n, |_, _| 1.0);
        let mut a = Mat::eye(n);
        a.axpy(1.0, &ones);
        let r = eigh(&a);
        for i in 0..n - 1 {
            assert!((r.values[i] - 1.0).abs() < 1e-9);
        }
        assert!((r.values[n - 1] - (1.0 + n as f64)).abs() < 1e-9);
    }

    #[test]
    fn leading_by_magnitude_ordering() {
        let a = Mat::diag(&[-5.0, 1.0, 3.0, -0.5]);
        let r = eigh(&a);
        let idx = r.leading_by_magnitude(2);
        let vals: Vec<f64> = idx.iter().map(|&i| r.values[i]).collect();
        assert_eq!(vals, vec![-5.0, 3.0]);
    }

    #[test]
    fn leading_orders_rank_nan_last_without_panicking() {
        // regression: a degenerate projected T can hand the sorts NaN
        // eigenvalues; partial_cmp().unwrap() used to panic here.
        let r = EighResult {
            values: vec![1.0, f64::NAN, -3.0, f64::NAN, 2.0],
            vectors: Mat::eye(5),
        };
        assert_eq!(r.leading_by_magnitude(5), vec![2, 4, 0, 1, 3]);
        assert_eq!(r.leading_by_magnitude(2), vec![2, 4]);
        assert_eq!(r.leading_by_value(5), vec![4, 0, 2, 1, 3]);
        // magnitude ties still break toward the positive eigenvalue
        let pm = EighResult { values: vec![-2.0, 2.0], vectors: Mat::eye(2) };
        assert_eq!(pm.leading_by_magnitude(2), vec![1, 0]);
    }

    #[test]
    fn eigh_into_matches_eigh_and_reuses_scratch() {
        let mut rng = Rng::new(21);
        let mut w = EighWork::new();
        for &n in &[7usize, 24, 3] {
            let a = rand_sym(n, &mut rng);
            let r = eigh(&a);
            eigh_into(&a, &mut w);
            assert_eq!(w.d, r.values);
            assert_eq!(w.v.as_slice(), r.vectors.as_slice());
        }
    }

    #[test]
    fn order_by_magnitude_into_reuses_index_buffer() {
        let mut idx = Vec::new();
        order_by_magnitude_into(&[1.0, -4.0, 2.0], 2, &mut idx);
        assert_eq!(idx, vec![1, 2]);
        order_by_magnitude_into(&[0.5, -0.5], 2, &mut idx);
        assert_eq!(idx, vec![0, 1]);
    }

    #[test]
    fn matches_power_iteration_top_eigenpair() {
        let mut rng = Rng::new(77);
        let a = rand_sym(40, &mut rng);
        // make it PSD-dominant so power iteration converges to top-|λ|
        let r = eigh(&a);
        let top = *r
            .values
            .iter()
            .max_by(|a, b| a.abs().partial_cmp(&b.abs()).unwrap())
            .unwrap();
        let mut v = vec![1.0; 40];
        for _ in 0..2000 {
            let w = blas::gemv(&a, &v);
            let n = blas::nrm2(&w);
            v = w.iter().map(|x| x / n).collect();
        }
        let rayleigh = blas::dot(&v, &blas::gemv(&a, &v));
        assert!((rayleigh.abs() - top.abs()).abs() < 1e-6);
    }
}
