//! Rank-guarded Cholesky and triangular inversion — the small dense
//! pieces of the CholeskyQR2 orthonormalizer (mirrors the pure-lax
//! implementation in python/compile/model.py).

use crate::linalg::mat::Mat;

/// Lower Cholesky factor of a PSD matrix with a pivot guard: when the
/// Schur-complement diagonal of column j falls below
/// `pivot_tol · max_diag(G)`, the column is replaced by e_j and flagged
/// dependent (so the inverse stays bounded and the dependent direction
/// maps to its tiny residual).  Returns (L, keep-flags).
pub fn cholesky_guarded(g: &Mat, pivot_tol: f64) -> (Mat, Vec<bool>) {
    let mut l = Mat::zeros(0, 0);
    let mut keep = Vec::new();
    cholesky_guarded_into(g, pivot_tol, &mut l, &mut keep);
    (l, keep)
}

/// [`cholesky_guarded`] writing L and the keep-flags into caller-owned
/// buffers (reshaped in place).
pub fn cholesky_guarded_into(g: &Mat, pivot_tol: f64, l: &mut Mat, keep: &mut Vec<bool>) {
    let m = g.rows();
    assert_eq!(m, g.cols());
    l.reset(m, m);
    keep.clear();
    keep.resize(m, true);
    let scale = (0..m).fold(0.0f64, |a, i| a.max(g.get(i, i))).max(1e-300);
    for j in 0..m {
        // c = G[:, j] − L[:, :j] · L[j, :j]ᵀ  (only rows ≥ j needed)
        let mut diag = g.get(j, j);
        for p in 0..j {
            diag -= l.get(j, p) * l.get(j, p);
        }
        if diag <= pivot_tol * scale {
            keep[j] = false;
            l.set(j, j, 1.0);
            continue;
        }
        let d = diag.sqrt();
        l.set(j, j, d);
        for i in j + 1..m {
            let mut v = g.get(i, j);
            for p in 0..j {
                v -= l.get(i, p) * l.get(j, p);
            }
            l.set(i, j, v / d);
        }
    }
}

/// Inverse of an upper-triangular matrix (back substitution, column by
/// column).  Panics on zero diagonal.
pub fn tri_inv_upper(r: &Mat) -> Mat {
    let m = r.rows();
    assert_eq!(m, r.cols());
    let mut x = Mat::zeros(m, m);
    for j in 0..m {
        // solve R x = e_j ; x supported on rows 0..=j
        x.set(j, j, 1.0 / r.get(j, j));
        for i in (0..j).rev() {
            let mut s = 0.0;
            for p in i + 1..=j {
                s += r.get(i, p) * x.get(p, j);
            }
            x.set(i, j, -s / r.get(i, i));
        }
    }
    x
}

/// Inverse of R = Lᵀ read directly off the *lower* factor `l` — the same
/// arithmetic as `tri_inv_upper(&l.t())` in the same operation order
/// (bitwise identical), minus the transpose copy.  Writes into a
/// caller-owned buffer.
pub fn tri_inv_upper_from_lower_into(l: &Mat, x: &mut Mat) {
    let m = l.rows();
    assert_eq!(m, l.cols());
    x.reset(m, m);
    for j in 0..m {
        // R[i, p] = L[p, i]
        x.set(j, j, 1.0 / l.get(j, j));
        for i in (0..j).rev() {
            let mut s = 0.0;
            for p in i + 1..=j {
                s += l.get(p, i) * x.get(p, j);
            }
            x.set(i, j, -s / l.get(i, i));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Rng;

    #[test]
    fn cholesky_reconstructs_spd() {
        let mut rng = Rng::new(1);
        for &m in &[1usize, 3, 10, 40] {
            let a = Mat::randn(m, m + 2, &mut rng);
            let mut g = a.matmul(&a.t());
            for i in 0..m {
                g.add_at(i, i, 0.5);
            }
            let (l, keep) = cholesky_guarded(&g, 1e-14);
            assert!(keep.iter().all(|&k| k));
            let rec = l.matmul(&l.t());
            let mut diff = rec;
            diff.axpy(-1.0, &g);
            assert!(diff.max_abs() < 1e-9, "m={m}");
        }
    }

    #[test]
    fn guard_flags_dependent_columns() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(20, 3, &mut rng);
        let mut panel = Mat::zeros(20, 5);
        for j in 0..3 {
            panel.set_col(j, a.col(j));
        }
        panel.set_col(3, a.col(0)); // duplicate
        // col 4 zero
        let g = panel.t_matmul(&panel);
        let (_, keep) = cholesky_guarded(&g, 1e-10);
        assert_eq!(keep, vec![true, true, true, false, false]);
    }

    #[test]
    fn tri_inv_from_lower_matches_transposed_path_bitwise() {
        let mut rng = Rng::new(4);
        for &m in &[1usize, 5, 20] {
            let a = Mat::randn(m, m + 3, &mut rng);
            let mut g = a.matmul(&a.t());
            for i in 0..m {
                g.add_at(i, i, 1.0);
            }
            let (l, _) = cholesky_guarded(&g, 1e-14);
            let want = tri_inv_upper(&l.t());
            let mut got = Mat::zeros(0, 0);
            tri_inv_upper_from_lower_into(&l, &mut got);
            assert_eq!(got.as_slice(), want.as_slice(), "m={m}");
        }
    }

    #[test]
    fn tri_inv_matches_identity() {
        let mut rng = Rng::new(3);
        for &m in &[1usize, 4, 17] {
            let mut r = Mat::zeros(m, m);
            for j in 0..m {
                for i in 0..=j {
                    r.set(i, j, rng.normal());
                }
                let d = r.get(j, j);
                r.set(j, j, d.signum() * (d.abs() + 1.0));
            }
            let rinv = tri_inv_upper(&r);
            let prod = r.matmul(&rinv);
            let mut eye = Mat::eye(m);
            eye.axpy(-1.0, &prod);
            assert!(eye.max_abs() < 1e-10, "m={m}");
        }
    }
}
