//! The kernel-pool dispatch protocol, extracted from any thread or
//! buffer ownership so it can be model-checked.
//!
//! This module is deliberately dependency-free: it imports only
//! [`crate::sync`] (the std/loom facade).  The `rust/loom-model` crate
//! includes this exact source file via `#[path]` and compiles it
//! against a `loom`-backed facade, so every lock/condvar line below is
//! explored under exhaustive interleaving by `cargo test` in that
//! crate (`--cfg loom`).  Keep it that way: no `anyhow`, no `Mat`, no
//! other crate modules.
//!
//! ## Protocol
//!
//! One caller at a time (callers are serialized by the owning
//! [`KernelPool`](crate::linalg::threads::KernelPool)) publishes a
//! descriptor: a cloneable job plus a chunk count.  Publication bumps
//! the **epoch** and wakes the parked workers; the caller then
//! *participates* — it claims and runs chunks exactly like a worker —
//! and finally blocks until every chunk has checked in, at which point
//! it retires the descriptor and returns.  All dispatch state (epoch,
//! descriptor, claim cursor, completion count, shutdown flag) lives
//! under a single mutex: chunk counts are tiny (≤ the thread budget,
//! ≤ 16), so one lock round-trip per claim is noise next to a chunk's
//! flop count, and the protocol needs no bare atomics — the mutex
//! orders everything, which is why this file has no `// ordering:`
//! sites for detlint to demand.
//!
//! ## Invariants (machine-checked in `rust/loom-model/tests/loom_pool.rs`)
//!
//! 1. **Every chunk runs exactly once before `publish_and_wait`
//!    returns.**  The claim cursor hands each index to exactly one
//!    claimant, and the caller waits for `completed == n_chunks`.  No
//!    lost wakeup: workers re-check the descriptor under the mutex
//!    before parking, and publication notifies while holding it.
//! 2. **No worker runs or completes a stale epoch's descriptor.**  A
//!    claim carries the epoch it was made under, and check-in asserts
//!    the descriptor it completes against is that same epoch.  (The
//!    descriptor is retired by the caller only after all check-ins, so
//!    a claimed chunk's descriptor cannot be replaced underneath it.)
//! 3. **Shutdown while a descriptor is in flight completes the call
//!    before workers exit**: a woken worker drains claimable work
//!    *before* honoring the shutdown flag, and because the caller
//!    participates, a publish that races shutdown (or finds every
//!    worker already gone) still completes — the caller drains the
//!    remaining chunks itself.
//!
//! A panicking chunk behaves like it did under `std::thread::scope`:
//! the check-in guard still checks in (marking the descriptor
//! poisoned), the caller's retire guard waits out the surviving chunks
//! and retires the descriptor even while unwinding, and the panic
//! surfaces on the calling thread — the pool itself stays usable.

use crate::sync::{Condvar, Mutex, MutexGuard};

/// A cloneable handle to one published kernel invocation: whoever
/// claims chunk `i` calls `run_chunk(i)` exactly once.  Production
/// erases a borrowed closure into a raw-pointer job (safe because the
/// publisher outlives every chunk — it blocks until all check-ins);
/// the loom models instantiate an `Arc`-counting probe job.
pub trait ChunkRunner: Clone {
    fn run_chunk(&self, chunk: usize);
}

/// One published kernel invocation.
struct Descriptor<J> {
    job: J,
    /// Epoch this descriptor was published under (invariant 2).
    epoch: u64,
    n_chunks: usize,
    /// Claim cursor: next unclaimed chunk index.
    next: usize,
    /// Chunks that have finished running and checked back in.
    completed: usize,
    /// Set when a chunk checked in by unwinding; the publisher
    /// re-raises after the call completes.
    poisoned: bool,
}

struct State<J> {
    /// Bumped once per publication; `u64` cannot wrap in practice.
    epoch: u64,
    /// The in-flight descriptor, if any.  At most one exists at a time
    /// (callers are serialized above this core).
    desc: Option<Descriptor<J>>,
    shutdown: bool,
}

/// The dispatch core: all protocol state under one mutex, a condvar
/// for parked workers, and a condvar for the waiting publisher.
pub struct DispatchCore<J: ChunkRunner> {
    state: Mutex<State<J>>,
    /// Workers park here; notified on publish and on shutdown.
    work_cv: Condvar,
    /// The publisher waits here for the last check-in.
    done_cv: Condvar,
}

/// Checks a claimed chunk back in on drop — on the normal path *and*
/// when the chunk body unwinds, so a panicking kernel can never strand
/// its publisher in the `done_cv` wait.
struct CheckIn<'a, J: ChunkRunner> {
    core: &'a DispatchCore<J>,
    epoch: u64,
}

impl<J: ChunkRunner> Drop for CheckIn<'_, J> {
    fn drop(&mut self) {
        let mut st = self.core.state.lock();
        let d = st.desc.as_mut().expect("descriptor retired before all of its chunks checked in");
        // Invariant 2: the descriptor we complete against is the one we
        // claimed from — a stale claim never completes a newer call.
        assert_eq!(d.epoch, self.epoch, "check-in against a stale epoch's descriptor");
        if std::thread::panicking() {
            d.poisoned = true;
        }
        d.completed += 1;
        if d.completed == d.n_chunks {
            self.core.done_cv.notify_all();
        }
    }
}

/// The publisher's completion barrier, run on drop so it also fires
/// while the caller unwinds from a panicking chunk of its own: wait for
/// every check-in, retire the descriptor, and surface poison.
struct WaitRetire<'a, J: ChunkRunner> {
    core: &'a DispatchCore<J>,
}

impl<J: ChunkRunner> Drop for WaitRetire<'_, J> {
    fn drop(&mut self) {
        let mut st = self.core.state.lock();
        let poisoned = loop {
            let d = st.desc.as_ref().expect("descriptor retired while its publisher waits");
            if d.completed == d.n_chunks {
                break d.poisoned;
            }
            st = self.core.done_cv.wait(st);
        };
        // Retire: late-waking workers see `desc == None` and park — this
        // call's job can never run again (invariant 2).
        st.desc = None;
        drop(st);
        if poisoned && !std::thread::panicking() {
            panic!("a kernel chunk panicked on a pool worker");
        }
    }
}

impl<J: ChunkRunner> Default for DispatchCore<J> {
    fn default() -> Self {
        DispatchCore::new()
    }
}

impl<J: ChunkRunner> DispatchCore<J> {
    pub fn new() -> DispatchCore<J> {
        DispatchCore {
            state: Mutex::new(State { epoch: 0, desc: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }
    }

    /// Publish `job` as `n_chunks` chunks, participate in running them,
    /// and return once every chunk has checked in.  Callers must be
    /// serialized externally (the pool holds a caller gate).
    pub fn publish_and_wait(&self, job: J, n_chunks: usize) {
        if n_chunks == 0 {
            return;
        }
        let mut st = self.state.lock();
        debug_assert!(st.desc.is_none(), "publish with a descriptor still in flight");
        st.epoch += 1;
        st.desc = Some(Descriptor {
            job,
            epoch: st.epoch,
            n_chunks,
            next: 0,
            completed: 0,
            poisoned: false,
        });
        // Wake every parked worker while holding the lock: a worker is
        // either mid-wait (receives the notification) or has not yet
        // re-checked `desc` (sees it before parking) — no lost wakeup.
        self.work_cv.notify_all();
        drop(st);
        let barrier = WaitRetire { core: self };
        let st = self.state.lock();
        drop(self.drain_claimable(st));
        // The barrier waits for straggler chunks claimed by workers,
        // retires the descriptor, and re-raises a chunk panic.
        drop(barrier);
    }

    /// Claim and run chunks until the current descriptor (if any) has
    /// none left to hand out.  Returns with the lock re-held.
    fn drain_claimable<'a>(&'a self, mut st: MutexGuard<'a, State<J>>) -> MutexGuard<'a, State<J>> {
        loop {
            let Some(d) = st.desc.as_mut() else { return st };
            if d.next >= d.n_chunks {
                return st;
            }
            let chunk = d.next;
            d.next += 1;
            let epoch = d.epoch;
            let job = d.job.clone();
            drop(st);
            {
                let _check_in = CheckIn { core: self, epoch };
                job.run_chunk(chunk);
            }
            st = self.state.lock();
        }
    }

    /// Body of one pool worker: park until work is published (or
    /// shutdown), drain claimable chunks, repeat.  Shutdown is honored
    /// only *after* the drain, so an in-flight descriptor is never
    /// abandoned (invariant 3).
    pub fn worker_loop(&self) {
        let mut st = self.state.lock();
        loop {
            st = self.drain_claimable(st);
            if st.shutdown {
                return;
            }
            st = self.work_cv.wait(st);
        }
    }

    /// Ask every worker to exit once currently-claimable work is
    /// drained.  Publishing after shutdown still completes (the caller
    /// drains its own chunks); it just runs without helpers.
    pub fn shutdown(&self) {
        let mut st = self.state.lock();
        st.shutdown = true;
        self.work_cv.notify_all();
        drop(st);
    }
}
