//! The f32-storage / f64-accumulate serving tier.
//!
//! Embedding *serving* (cosine top-k, k-means distance scans) is
//! bandwidth-bound: every query streams the whole n×k panel while the
//! arithmetic per element is one multiply-add.  Storing the panel in
//! f32 halves the bytes moved; accumulating in f64 keeps the reduction
//! error at f64 scale, so the only precision loss is the one-time
//! rounding of each stored value to f32 (relative error ≤ 2⁻²⁴ per
//! entry, hence ~2⁻²⁴-relative on dots of well-conditioned rows — the
//! documented tolerance of the rank-stability tests).
//!
//! [`F32Mat`] is **row-major** — the opposite of [`Mat`] — because the
//! serving scans are row-wise (one embedding row per node): a cosine
//! sweep reads rows contiguously instead of striding column-major
//! memory, which is the second half of the win.
//!
//! The tier is **opt-in** ([`ServePrecision`] defaults to `F64`): the
//! f64 snapshot path stays the oracle, and nothing in the update step
//! ever touches f32.

use crate::linalg::mat::Mat;

/// Precision knob for the read-side serving kernels
/// (`ServiceConfig::serve_precision`, `QueryEngine`, and the k-means
/// distance phases).  `F64` — the default — is the oracle path; `F32`
/// opts into f32-storage/f64-accumulate serving.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ServePrecision {
    /// Serve from the f64 snapshot (bit-for-bit the historical results).
    #[default]
    F64,
    /// Serve from a row-major f32 copy of the panel, accumulating in
    /// f64 (documented ~2⁻²⁴-relative drift; top-k ranks stable on
    /// conditioned inputs).
    F32,
}

/// Row-major f32 matrix: the serving-tier storage format.
#[derive(Clone, Debug, PartialEq)]
pub struct F32Mat {
    rows: usize,
    cols: usize,
    /// `data[i * cols + j]` — row `i` is contiguous.
    data: Vec<f32>,
}

impl F32Mat {
    /// Demote a column-major [`Mat`] to row-major f32 (each entry
    /// rounds to nearest).
    pub fn from_mat(m: &Mat) -> F32Mat {
        F32Mat::from_mat_in(m, Vec::new())
    }

    /// [`F32Mat::from_mat`] reusing a recycled buffer's capacity (see
    /// `StepWorkspace::take_f32_buf`).
    pub fn from_mat_in(m: &Mat, mut buf: Vec<f32>) -> F32Mat {
        let (rows, cols) = (m.rows(), m.cols());
        buf.clear();
        buf.reserve(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                buf.push(m.get(i, j) as f32);
            }
        }
        F32Mat { rows, cols, data: buf }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i` as a contiguous slice (the serving access pattern).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Take the backing buffer (for workspace recycling).
    #[inline]
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }
}

/// Dot product with f32 loads and f64 accumulation, 4-way unrolled like
/// `blas::dot` (the lanes only re-associate the f64 sums — the f32
/// storage rounding dominates the error budget either way).
#[inline]
pub fn dot_f32(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        s0 += f64::from(x[i]) * f64::from(y[i]);
        s1 += f64::from(x[i + 1]) * f64::from(y[i + 1]);
        s2 += f64::from(x[i + 2]) * f64::from(y[i + 2]);
        s3 += f64::from(x[i + 3]) * f64::from(y[i + 3]);
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += f64::from(x[i]) * f64::from(y[i]);
    }
    s
}

/// Fused `(x·y, y·y)` in one sweep over `y` — the per-row work of a
/// cosine scan (dot against the query plus the row's own norm).
#[inline]
pub fn dot_norm2_f32(x: &[f32], y: &[f32]) -> (f64, f64) {
    debug_assert_eq!(x.len(), y.len());
    let mut dot = 0.0f64;
    let mut nn = 0.0f64;
    for (&xi, &yi) in x.iter().zip(y.iter()) {
        let yv = f64::from(yi);
        dot += f64::from(xi) * yv;
        nn += yv * yv;
    }
    (dot, nn)
}

/// y = A·x with f32 loads and f64 accumulation: one dot per (contiguous)
/// row — the f32 serving twin of `blas::gemv` on a row-major panel.
pub fn gemv_f32(a: &F32Mat, x: &[f32]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| dot_f32(a.row(i), x)).collect()
}

/// Squared Euclidean distance from row `i` of `a` to `center`, with
/// f32 loads and f64 differences/accumulation — the k-means distance
/// phase at `ServePrecision::F32`.
#[inline]
pub fn row_dist2_f32(a: &F32Mat, i: usize, center: &[f32]) -> f64 {
    debug_assert_eq!(center.len(), a.cols());
    let row = a.row(i);
    let mut s = 0.0f64;
    for (&v, &c) in row.iter().zip(center.iter()) {
        let diff = f64::from(v) - f64::from(c);
        s += diff * diff;
    }
    s
}

/// Demote an f64 slice into a reused f32 buffer (cleared first).
pub fn demote_into(src: &[f64], dst: &mut Vec<f32>) {
    dst.clear();
    dst.extend(src.iter().map(|&v| v as f32));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::rng::Rng;

    #[test]
    fn from_mat_is_rowmajor_rounding() {
        let m = Mat::from_rows(2, 3, &[1.0, 2.5, -3.0, 4.0, 0.0, 6.25]);
        let f = F32Mat::from_mat(&m);
        assert_eq!((f.rows(), f.cols()), (2, 3));
        assert_eq!(f.row(0), &[1.0f32, 2.5, -3.0]);
        assert_eq!(f.row(1), &[4.0f32, 0.0, 6.25]);
        assert_eq!(f.get(1, 2), 6.25f32);
        // a value that does not fit f32 exactly rounds to nearest
        let m2 = Mat::from_rows(1, 1, &[1.0 + 1e-12]);
        let f2 = F32Mat::from_mat(&m2);
        assert_eq!(f2.get(0, 0), 1.0f32);
    }

    #[test]
    fn from_mat_in_reuses_capacity() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(10, 4, &mut rng);
        let f = F32Mat::from_mat(&m);
        let buf = f.into_vec();
        let ptr = buf.as_ptr();
        let cap = buf.capacity();
        let m2 = Mat::randn(8, 5, &mut rng);
        let f2 = F32Mat::from_mat_in(&m2, buf);
        assert_eq!((f2.rows(), f2.cols()), (8, 5));
        let buf2 = f2.into_vec();
        assert_eq!(buf2.as_ptr(), ptr, "same-or-smaller request reuses the buffer");
        assert_eq!(buf2.capacity(), cap);
    }

    #[test]
    fn dot_f32_tracks_f64_dot_within_storage_rounding() {
        let mut rng = Rng::new(2);
        for &n in &[1usize, 3, 4, 7, 64, 257] {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            let want = blas::dot(&x, &y);
            let got = dot_f32(&xf, &yf);
            // per-entry storage rounding ≤ 2⁻²⁴ relative; the f64
            // accumulation adds nothing at this scale
            let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum::<f64>().max(1.0);
            assert!(
                (got - want).abs() <= 4.0 * scale * 2f64.powi(-24),
                "n={n}: {got} vs {want}"
            );
            let (d2, nn) = dot_norm2_f32(&xf, &yf);
            assert_eq!(d2.to_bits(), {
                // dot_norm2 accumulates in one lane; compare against the
                // same sequential reduction
                let mut s = 0.0f64;
                for i in 0..n {
                    s += f64::from(xf[i]) * f64::from(yf[i]);
                }
                s.to_bits()
            });
            assert!(nn >= 0.0);
        }
    }

    #[test]
    fn gemv_f32_matches_f64_gemv_within_tolerance() {
        let mut rng = Rng::new(3);
        let m = Mat::randn(40, 9, &mut rng);
        let x: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let want = blas::gemv(&m, &x);
        let a = F32Mat::from_mat(&m);
        let mut xf = Vec::new();
        demote_into(&x, &mut xf);
        let got = gemv_f32(&a, &xf);
        for i in 0..40 {
            assert!((got[i] - want[i]).abs() < 1e-5 * (1.0 + want[i].abs()), "row {i}");
        }
    }

    #[test]
    fn row_dist2_f32_matches_f64_within_tolerance() {
        let mut rng = Rng::new(4);
        let m = Mat::randn(20, 6, &mut rng);
        let a = F32Mat::from_mat(&m);
        let center: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let mut c32 = Vec::new();
        demote_into(&center, &mut c32);
        for i in 0..20 {
            let want: f64 = (0..6).map(|j| (m.get(i, j) - center[j]).powi(2)).sum();
            let got = row_dist2_f32(&a, i, &c32);
            assert!((got - want).abs() < 1e-5 * (1.0 + want), "row {i}: {got} vs {want}");
        }
    }
}
