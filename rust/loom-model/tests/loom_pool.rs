//! Exhaustive interleaving checks of the worker-pool scheduler
//! protocol, the memo cache, and the kernel pool's dispatch protocol —
//! the machine proofs behind the invariants stated in
//! `rust/src/coordinator/pool_core.rs`, `rust/src/linalg/kernel_core.rs`,
//! and `docs/CONCURRENCY.md`.
//!
//! Run the real model check with:
//!
//! ```text
//! cd rust/loom-model
//! RUSTFLAGS="--cfg loom -C debug-assertions=on" \
//!   LOOM_MAX_PREEMPTIONS=3 cargo test --release --test loom_pool
//! ```
//!
//! Without `--cfg loom` the same tests compile against the production
//! std facade and each run once as a plain smoke test, so `cargo test`
//! in this directory always exercises the code paths.
//!
//! Thread budget: loom's default `MAX_THREADS` is 4 including the model
//! main thread; every model below spawns at most 2 threads.

use std::collections::VecDeque;
use std::time::Instant;

use grest_loom_model::kernel_core::{ChunkRunner, DispatchCore};
use grest_loom_model::memo_core::{Memo, MemoHow};
use grest_loom_model::pool_core::{PoolCore, StepOutcome, Stepper, SubmitError};
use grest_loom_model::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use grest_loom_model::sync::{thread, Arc, Mutex};

#[cfg(loom)]
fn model(f: impl Fn() + Sync + Send + 'static) {
    loom::model(f);
}

#[cfg(not(loom))]
fn model(f: impl Fn() + Sync + Send + 'static) {
    f();
}

enum Cmd {
    Work,
    Stop,
}

/// Counters shared between the probe stepper inside the pool and the
/// model's final assertions.  SeqCst throughout: these are the
/// *observers*, not the protocol under test, and must not themselves
/// introduce subtle ordering.
struct Obs {
    steps: AtomicUsize,
    processed: AtomicUsize,
    drains: AtomicUsize,
    in_step: AtomicBool,
    acked: AtomicBool,
}

impl Obs {
    fn new() -> Arc<Obs> {
        Arc::new(Obs {
            steps: AtomicUsize::new(0),
            processed: AtomicUsize::new(0),
            drains: AtomicUsize::new(0),
            in_step: AtomicBool::new(false),
            acked: AtomicBool::new(false),
        })
    }
}

/// Minimal `Stepper` that *asserts the pool's contract from the inside*:
/// no concurrent steps for one tenant, no step after retirement, no
/// deadline drain after retirement.
struct Probe {
    obs: Arc<Obs>,
    /// Arm a due-immediately deadline after the first inbox drain
    /// (models a `BatchPolicy::MaxAge` pending batch).
    wait_once: bool,
    armed: bool,
    stopped: bool,
}

impl Probe {
    fn new(obs: Arc<Obs>, wait_once: bool) -> Probe {
        Probe { obs, wait_once, armed: false, stopped: false }
    }
}

impl Stepper for Probe {
    type Cmd = Cmd;

    fn step(&mut self, inbox: &Mutex<VecDeque<Cmd>>) -> StepOutcome {
        assert!(!self.stopped, "a retired tenant must never be stepped again");
        assert!(
            !self.obs.in_step.swap(true, Ordering::SeqCst),
            "two workers stepped one tenant concurrently"
        );
        self.obs.steps.fetch_add(1, Ordering::SeqCst);
        loop {
            let cmd = inbox.lock().pop_front();
            match cmd {
                None => break,
                Some(Cmd::Work) => {
                    self.obs.processed.fetch_add(1, Ordering::SeqCst);
                }
                Some(Cmd::Stop) => {
                    self.stopped = true;
                    let obs = self.obs.clone();
                    self.obs.in_step.store(false, Ordering::SeqCst);
                    return StepOutcome::Stopped(Box::new(move || {
                        obs.acked.store(true, Ordering::SeqCst);
                    }));
                }
            }
        }
        let outcome = if self.wait_once && !self.armed {
            self.armed = true;
            StepOutcome::WaitUntil(Instant::now())
        } else {
            StepOutcome::Idle
        };
        self.obs.in_step.store(false, Ordering::SeqCst);
        outcome
    }

    fn drain_deadline(&mut self) {
        assert!(!self.stopped, "a retired tenant must never have its deadline drained");
        self.obs.drains.fetch_add(1, Ordering::SeqCst);
    }
}

/// Invariant 1 (no lost wakeups): two racing submitters against a live
/// worker — every `Ok` submit is processed before the pool quiesces, in
/// every interleaving of the push / `queued` CAS / clear / re-check
/// protocol.  A stranded command (inbox non-empty, nobody queued) fails
/// the `processed == 2` assertion.
#[test]
fn submit_vs_turn_never_loses_a_command() {
    model(|| {
        let obs = Obs::new();
        let core = Arc::new(PoolCore::new());
        let tenant = core.register(Probe::new(obs.clone(), false));
        let worker = {
            let core = core.clone();
            thread::spawn_named("worker", move || core.worker_loop())
        };
        let submitter = {
            let (core, tenant) = (core.clone(), tenant.clone());
            thread::spawn_named("submitter", move || {
                core.submit(&tenant, Cmd::Work).expect("tenant is live");
            })
        };
        core.submit(&tenant, Cmd::Work).expect("tenant is live");
        submitter.join().expect("submitter thread");
        core.begin_shutdown();
        worker.join().expect("worker thread");

        assert_eq!(obs.processed.load(Ordering::SeqCst), 2, "a submitted command was lost");
        assert_eq!(tenant.inbox_len(), 0, "inbox must end empty");
        assert!(!tenant.is_queued(), "a live drained tenant must not stay queued");
    });
}

/// Invariant 2 (at-most-one-worker-per-tenant): two workers, one tenant
/// whose first turn arms a due-immediately deadline, two submits racing
/// the timer promotion.  The probe's `in_step` swap asserts the
/// exclusion from inside every turn; the counters assert no command is
/// lost or doubled while promotion and submission race for the same
/// `queued` flag.
#[test]
fn timer_promotion_respects_the_queued_exclusion() {
    model(|| {
        let obs = Obs::new();
        let core = Arc::new(PoolCore::new());
        let tenant = core.register(Probe::new(obs.clone(), true));
        let w1 = {
            let core = core.clone();
            thread::spawn_named("w1", move || core.worker_loop())
        };
        let w2 = {
            let core = core.clone();
            thread::spawn_named("w2", move || core.worker_loop())
        };
        core.submit(&tenant, Cmd::Work).expect("tenant is live");
        core.submit(&tenant, Cmd::Work).expect("tenant is live");
        core.begin_shutdown();
        w1.join().expect("worker 1");
        w2.join().expect("worker 2");

        assert_eq!(obs.processed.load(Ordering::SeqCst), 2, "a submitted command was lost");
        assert_eq!(tenant.inbox_len(), 0, "inbox must end empty");
        assert!(obs.drains.load(Ordering::SeqCst) <= 1, "a deadline drained more than once");
    });
}

/// Invariant 3 (retirement latch): a `Stop` races a `Work` submitter.
/// After quiescence: the stop was acknowledged exactly once, the
/// `queued` latch stays armed forever, the inbox is empty (a raced
/// submitter's command is discarded, never executed post-stop — the
/// probe asserts that from the inside), and fresh submits fail.
#[test]
fn retirement_latches_and_discards_racing_submits() {
    model(|| {
        let obs = Obs::new();
        let core = Arc::new(PoolCore::new());
        let tenant = core.register(Probe::new(obs.clone(), false));
        let worker = {
            let core = core.clone();
            thread::spawn_named("worker", move || core.worker_loop())
        };
        let racer = {
            let (core, tenant) = (core.clone(), tenant.clone());
            thread::spawn_named("racer", move || {
                // Ok (enqueued while live — may still be discarded by
                // the retirement) or a clean TenantStopped; never a
                // hang, never PoolShutdown here.
                if let Err(e) = core.submit(&tenant, Cmd::Work) {
                    assert_eq!(e, SubmitError::TenantStopped);
                }
            })
        };
        core.submit(&tenant, Cmd::Stop).expect("tenant is live at the stop submit");
        racer.join().expect("racer thread");
        core.begin_shutdown();
        worker.join().expect("worker thread");

        assert!(obs.acked.load(Ordering::SeqCst), "retirement was never acknowledged");
        assert!(tenant.is_stopped());
        assert!(tenant.is_queued(), "the queued latch must stay armed after retirement");
        assert_eq!(tenant.inbox_len(), 0, "inbox must end empty");
        assert!(obs.processed.load(Ordering::SeqCst) <= 1, "a discarded command executed");
        assert_eq!(core.submit(&tenant, Cmd::Work), Err(SubmitError::TenantStopped));
    });
}

/// Satellite fix under model check: a turn arms a `WaitUntil` deadline
/// while the pool shuts down.  In every interleaving the pending work
/// runs exactly once — promoted into a second turn, or drained by
/// `begin_shutdown` / the `add_timer` shutdown path — never stranded,
/// never doubled.
#[test]
fn shutdown_flushes_an_armed_deadline_exactly_once() {
    model(|| {
        let obs = Obs::new();
        let core = Arc::new(PoolCore::new());
        let tenant = core.register(Probe::new(obs.clone(), true));
        let worker = {
            let core = core.clone();
            thread::spawn_named("worker", move || core.worker_loop())
        };
        core.submit(&tenant, Cmd::Work).expect("tenant is live");
        core.begin_shutdown();
        worker.join().expect("worker thread");

        assert_eq!(obs.processed.load(Ordering::SeqCst), 1);
        // Exactly one of: the timer was promoted into a second turn
        // (steps == 2, drains == 0), or shutdown drained the armed
        // deadline inline (steps == 1, drains == 1).
        let steps = obs.steps.load(Ordering::SeqCst);
        let drains = obs.drains.load(Ordering::SeqCst);
        assert_eq!(
            steps + drains,
            2,
            "armed deadline stranded or doubled (steps {steps}, drains {drains})"
        );
    });
}

// ---------------------------------------------------------------------
// kernel pool (linalg/kernel_core.rs)

/// Probe job for the kernel dispatch core: counts how many times each
/// chunk index runs.  SeqCst observer counters, same convention as
/// [`Obs`].
#[derive(Clone)]
struct CountJob {
    counts: Arc<Vec<AtomicUsize>>,
}

impl ChunkRunner for CountJob {
    fn run_chunk(&self, chunk: usize) {
        self.counts[chunk].fetch_add(1, Ordering::SeqCst);
    }
}

fn chunk_counts(n: usize) -> Arc<Vec<AtomicUsize>> {
    Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect())
}

fn assert_each_ran_once(counts: &[AtomicUsize]) {
    for (i, c) in counts.iter().enumerate() {
        assert_eq!(c.load(Ordering::SeqCst), 1, "chunk {i} did not run exactly once");
    }
}

/// Kernel invariant 1 (publish → pickup → check-in, no lost wakeup):
/// a publisher races one parked worker over a 3-chunk descriptor.  In
/// every interleaving of the publish-notify vs. the worker's
/// park/drain cycle, every chunk runs exactly once before
/// `publish_and_wait` returns — whether the worker claims chunks or
/// the participating caller drains them all itself.
#[test]
fn kernel_publish_runs_every_chunk_exactly_once() {
    model(|| {
        let counts = chunk_counts(3);
        let core = Arc::new(DispatchCore::new());
        let worker = {
            let core = core.clone();
            thread::spawn_named("kernel-worker", move || core.worker_loop())
        };
        core.publish_and_wait(CountJob { counts: counts.clone() }, 3);
        assert_each_ran_once(&counts);
        core.shutdown();
        worker.join().expect("kernel worker");
    });
}

/// Kernel invariant 2 (no stale-epoch execution): two back-to-back
/// publishes through one worker.  A worker waking late from the first
/// notification must never re-run the retired first descriptor —
/// `CheckIn` asserts the epoch match from the inside, and the counters
/// assert neither call's chunks run twice or leak into the other.
#[test]
fn kernel_second_publish_never_reruns_the_first() {
    model(|| {
        let first = chunk_counts(2);
        let second = chunk_counts(2);
        let core = Arc::new(DispatchCore::new());
        let worker = {
            let core = core.clone();
            thread::spawn_named("kernel-worker", move || core.worker_loop())
        };
        core.publish_and_wait(CountJob { counts: first.clone() }, 2);
        core.publish_and_wait(CountJob { counts: second.clone() }, 2);
        assert_each_ran_once(&first);
        assert_each_ran_once(&second);
        core.shutdown();
        worker.join().expect("kernel worker");
    });
}

/// Kernel invariant 3 (shutdown-in-flight completes the call): a
/// shutdown races a publish against one worker.  Whatever the
/// ordering — worker exits before the publish, claims chunks first,
/// or wakes into the shutdown flag mid-descriptor — the participating
/// caller completes every chunk exactly once and both helper threads
/// join cleanly.
#[test]
fn kernel_shutdown_in_flight_completes_the_call() {
    model(|| {
        let counts = chunk_counts(2);
        let core = Arc::new(DispatchCore::new());
        let worker = {
            let core = core.clone();
            thread::spawn_named("kernel-worker", move || core.worker_loop())
        };
        let stopper = {
            let core = core.clone();
            thread::spawn_named("stopper", move || core.shutdown())
        };
        core.publish_and_wait(CountJob { counts: counts.clone() }, 2);
        assert_each_ran_once(&counts);
        stopper.join().expect("stopper thread");
        worker.join().expect("kernel worker");
    });
}

// ---------------------------------------------------------------------
// memo cache (coordinator/memo_core.rs)

/// Memo-cache contract: two racing `get_or_compute` calls for one key
/// run the compute closure exactly once; the loser observes the
/// winner's value, and the settled slot answers as a pure hit.
#[test]
fn memo_computes_exactly_once_across_racing_readers() {
    model(|| {
        let memo: Arc<Memo<u32, u32>> = Arc::new(Memo::new(4));
        let computes = Arc::new(Mutex::new(0u32));
        let reader = {
            let (memo, computes) = (memo.clone(), computes.clone());
            thread::spawn_named("reader", move || {
                let (v, _) = memo.get_or_compute(7, || {
                    *computes.lock() += 1;
                    77
                });
                assert_eq!(v, 77);
            })
        };
        let (v, _) = memo.get_or_compute(7, || {
            *computes.lock() += 1;
            77
        });
        assert_eq!(v, 77);
        reader.join().expect("reader thread");

        assert_eq!(*computes.lock(), 1, "the compute closure ran more than once");
        assert_eq!(memo.len(), 1);
        let (v, how) = memo.get_or_compute(7, || panic!("a settled slot must not recompute"));
        assert_eq!((v, how), (77, MemoHow::Hit));
    });
}
