//! Loom model-checking harness for the grest worker-pool scheduler and
//! the memo cache.
//!
//! The modules under test are **the production sources**, included by
//! `#[path]` — not copies.  `coordinator/pool_core.rs`,
//! `coordinator/memo_core.rs`, and `linalg/kernel_core.rs` (the kernel
//! pool's dispatch protocol) in the main crate import all their
//! concurrency primitives from `crate::sync`, so compiling them here
//! against a loom-backed `sync` module puts the exact shipped
//! lock/CAS/condvar protocol under exhaustive interleaving exploration.
//!
//! Two flavors:
//!
//! * `--cfg loom`: `sync` is [`sync_loom`]-backed; `loom::model` in the
//!   `tests/` directory explores every interleaving.
//! * default: `sync` is the main crate's std facade, and the same tests
//!   run once each as plain smoke tests.

#[cfg(loom)]
#[path = "sync_loom.rs"]
pub mod sync;

#[cfg(not(loom))]
#[path = "../../src/sync.rs"]
pub mod sync;

#[path = "../../src/coordinator/pool_core.rs"]
pub mod pool_core;

#[path = "../../src/coordinator/memo_core.rs"]
pub mod memo_core;

#[path = "../../src/linalg/kernel_core.rs"]
pub mod kernel_core;
