//! The loom twin of the main crate's `sync` facade (`rust/src/sync.rs`).
//!
//! Only the surface actually consumed by `pool_core`, `memo_core`, and
//! the model tests is mirrored: `atomic`, `Arc`, `Mutex`, `Condvar`,
//! `OnceSlot`, and `thread::spawn_named`.  Two deliberate deviations
//! from the std flavor:
//!
//! * `Mutex` is sized-only (loom's mutex does not support unsized
//!   payloads); nothing under model check needs `?Sized`.
//! * `OnceSlot` is a `Mutex<Option<T>>` — loom has no `OnceLock` — which
//!   models the same contract the std flavor gets from
//!   `OnceLock::get_or_init`: at most one in-flight initializer, racing
//!   readers block on it.

pub use loom::sync::atomic;
pub use loom::sync::{Arc, MutexGuard};

/// Loom mutex with the facade's panic-on-poison `lock()` signature.
#[derive(Debug)]
pub struct Mutex<T>(loom::sync::Mutex<T>);

impl<T> Mutex<T> {
    pub fn new(value: T) -> Mutex<T> {
        Mutex(loom::sync::Mutex::new(value))
    }

    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.0.lock().expect("mutex poisoned: a thread panicked while holding this lock")
    }
}

/// Loom condvar with the facade's guard-in/guard-out wait methods.
#[derive(Debug, Default)]
pub struct Condvar(loom::sync::Condvar);

impl Condvar {
    pub fn new() -> Condvar {
        Condvar(loom::sync::Condvar::new())
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.0.wait(guard).expect("mutex poisoned during condvar wait")
    }

    /// Wait with a timeout; returns the reacquired guard and whether the
    /// wait timed out.  Loom models the timeout nondeterministically —
    /// both the fired and the notified branch are explored.
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let (guard, res) =
            self.0.wait_timeout(guard, dur).expect("mutex poisoned during condvar wait");
        (guard, res.timed_out())
    }

    pub fn notify_one(&self) {
        self.0.notify_one();
    }

    pub fn notify_all(&self) {
        self.0.notify_all();
    }
}

/// Write-once cell for `Clone` values (see the std flavor's docs).
#[derive(Debug)]
pub struct OnceSlot<T>(Mutex<Option<T>>);

impl<T: Clone> OnceSlot<T> {
    pub fn new() -> OnceSlot<T> {
        OnceSlot(Mutex::new(None))
    }

    /// The value, if some caller already initialized the slot.
    pub fn try_get(&self) -> Option<T> {
        self.0.lock().clone()
    }

    /// The value, initializing the slot with `f` if empty.  Holding the
    /// slot lock across `f` is exactly the contract under test: one
    /// in-flight compute, racing readers block on it.
    pub fn get_or_init(&self, f: impl FnOnce() -> T) -> T {
        let mut slot = self.0.lock();
        if let Some(v) = &*slot {
            return v.clone();
        }
        let v = f();
        *slot = Some(v.clone());
        v
    }
}

impl<T: Clone> Default for OnceSlot<T> {
    fn default() -> OnceSlot<T> {
        OnceSlot::new()
    }
}

pub mod thread {
    //! Model-thread spawning; names are dropped (loom threads are
    //! anonymous).

    pub use loom::thread::JoinHandle;

    pub fn spawn_named(_name: &str, f: impl FnOnce() + Send + 'static) -> JoinHandle<()> {
        loom::thread::spawn(f)
    }
}
