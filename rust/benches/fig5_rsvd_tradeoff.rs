//! Bench: reproduce paper Fig. 5 — the RSVD complexity/accuracy
//! trade-off on CM-Collab: mean ψ difference vs G-REST₃ and the runtime
//! speedup ratio, over an (L, P) grid.

mod common;

use grest::eval::experiments::fig5_rsvd_tradeoff;

fn main() {
    let cfg = common::bench_config();
    let grid: Vec<usize> = if cfg.mc <= 1 && cfg.t_override.is_some() {
        vec![8, 16]
    } else {
        vec![10, 20, 40, 80]
    };
    println!("# Fig. 5 — RSVD (L, P) trade-off on CM-Collab, grid {grid:?}");
    let t = common::timed("fig5_rsvd_tradeoff", || fig5_rsvd_tradeoff(&cfg, &grid));
    println!("\n{}", t.render());
    let _ = t.write_csv("fig5");
}
