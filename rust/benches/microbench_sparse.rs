//! Sparse-path micro-benchmarks (the §Perf instrument for the
//! incremental update engine):
//!
//! * event-sourced Δ assembly (`DeltaBuilder::prepare`, O(|batch|))
//!   vs the old rebuild+diff path (`graph.adjacency()` +
//!   `Delta::from_diff`, O(nnz(A)·log)) across batch AND graph sizes —
//!   the incremental numbers should track the batch size, the rebuild
//!   numbers the graph size;
//! * incremental `Csr::apply_delta` row-merge vs a from-scratch
//!   adjacency rebuild;
//! * the row-partitioned SpMM thread ladder, with a bitwise-equality
//!   spot check of the `--threads` determinism contract.
//!
//! Emits `BENCH_sparse.json` (name → {n, seconds}) next to
//! `BENCH_linalg.json`.  `GREST_BENCH_QUICK=1` shrinks every size for
//! CI smoke runs.

mod common;

use grest::graph::stream::{DeltaBuilder, GraphEvent};
use grest::linalg::mat::Mat;
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::sparse::delta::Delta;

struct BenchRecord {
    name: String,
    n: usize,
    seconds: f64,
}

fn record(records: &mut Vec<BenchRecord>, name: &str, n: usize, seconds: f64) {
    records.push(BenchRecord { name: name.to_string(), n, seconds });
}

fn write_json(records: &[BenchRecord]) {
    let mut out = String::from("{\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  \"{}\": {{\"n\": {}, \"seconds\": {:.6e}}}{}\n",
            r.name,
            r.n,
            r.seconds,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    let path = "BENCH_sparse.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("# wrote {path} ({} entries)", records.len()),
        Err(e) => eprintln!("# failed to write {path}: {e}"),
    }
}

fn main() {
    let quick = std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rng = Rng::new(1);

    // ---- Δ-assembly ladder: event-sourced vs rebuild+diff
    let graph_sizes: &[usize] = if quick { &[2_000, 8_000] } else { &[20_000, 80_000] };
    let batch_sizes: &[usize] = &[16, 64, 256, 1024];
    for &n in graph_sizes {
        let w = grest::graph::generators::power_law_weights(n, 2.2, 5 * n);
        let g = grest::graph::generators::chung_lu(&w, &mut rng);
        let committed = g.adjacency();
        let edges = g.edges();
        println!("# graph n={} edges={}", g.n_nodes(), g.n_edges());
        for &batch in batch_sizes {
            let mut b = DeltaBuilder::from_graph(g.clone());
            // mixed batch: ~3/5 adds among existing nodes, 1/5 removals
            // of known edges, 1/5 expansion edges to unseen ids
            for i in 0..batch {
                if i % 5 == 3 {
                    let (u, v) = edges[rng.below(edges.len())];
                    b.push(GraphEvent::RemoveEdge(u as u64, v as u64));
                } else if i % 5 == 4 {
                    b.push(GraphEvent::AddEdge(rng.below(n) as u64, (n + i) as u64));
                } else {
                    b.push(GraphEvent::AddEdge(rng.below(n) as u64, rng.below(n) as u64));
                }
            }
            let s = common::micro_secs(
                &format!("prepare event-sourced   n={n} batch={batch}"),
                300,
                || {
                    std::hint::black_box(b.prepare());
                },
            );
            record(&mut records, &format!("prepare_incremental_n{n}_b{batch}"), batch, s);
            let s = common::micro_secs(
                &format!("prepare rebuild+diff   n={n} batch={batch}"),
                300,
                || {
                    let adj = b.graph().adjacency();
                    std::hint::black_box(Delta::from_diff(&committed, &adj));
                },
            );
            record(&mut records, &format!("prepare_rebuild_n{n}_b{batch}"), batch, s);
            if let Some(delta) = b.prepare() {
                let s = common::micro_secs(
                    &format!("apply_delta row-merge  n={n} batch={batch}"),
                    300,
                    || {
                        std::hint::black_box(committed.apply_delta(&delta));
                    },
                );
                record(&mut records, &format!("apply_delta_n{n}_b{batch}"), batch, s);
            }
        }
        let s = common::micro_secs(&format!("adjacency full rebuild n={n}"), 300, || {
            std::hint::black_box(g.adjacency());
        });
        record(&mut records, &format!("adjacency_rebuild_n{n}"), n, s);
    }

    // ---- SpMM thread ladder (row-partitioned single-pass kernel)
    let n = if quick { 4096 } else { 16384 };
    let k = 64;
    let w = grest::graph::generators::power_law_weights(n, 2.2, 6 * n);
    let g = grest::graph::generators::chung_lu(&w, &mut rng);
    let a = g.adjacency();
    let x = Mat::randn(n, k, &mut rng);
    println!("# spmm graph: {} nodes {} edges, panel k={k}", g.n_nodes(), g.n_edges());
    let mut base = f64::NAN;
    for &t in &[1usize, 2, 4, 8] {
        let s = common::micro_secs(&format!("spmm A·X threads={t}"), 500, || {
            std::hint::black_box(a.matmul_dense_with(&x, Threads(t)));
        });
        if t == 1 {
            base = s;
        }
        println!("# spmm speedup @ {t} threads: {:.2}x", base / s);
        record(&mut records, &format!("spmm_ax_t{t}"), n, s);
    }
    // the determinism contract behind --threads N
    let seq = a.matmul_dense_with(&x, Threads::SINGLE);
    let par = a.matmul_dense_with(&x, Threads(4));
    assert_eq!(
        seq.as_slice(),
        par.as_slice(),
        "spmm must be bitwise stable across thread counts"
    );
    println!("# spmm bitwise-stable across thread counts: OK");

    write_json(&records);
}
