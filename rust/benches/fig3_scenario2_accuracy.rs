//! Bench: reproduce paper Fig. 3 — eigenvector approximation accuracy on
//! graphs with timestamped edges (Scenario 2, Type-D datasets).

mod common;

use grest::eval::experiments::figure_accuracy_runtime;
use grest::graph::datasets::Kind;

fn main() {
    let cfg = common::bench_config();
    println!("# Fig. 3 — Scenario 2 accuracy (K={}, angles over {}, MC={})", cfg.k, cfg.angles_k, cfg.mc);
    let (_, ta, tb, _) = common::timed("fig3_scenario2_accuracy", || {
        figure_accuracy_runtime(Kind::Dynamic, &cfg)
    });
    println!("\n## Fig. 3(a): time-averaged psi, leading 3 eigenvectors\n{}", ta.render());
    println!("## Fig. 3(b): mean psi over leading {} vs t\n{}", cfg.angles_k, tb.render());
    let _ = ta.write_csv("fig3_a");
    let _ = tb.write_csv("fig3_b");
}
