//! Bench: reproduce paper Table 3 — accuracy of detecting central nodes
//! via subgraph centrality, J ∈ {100, 1000}, on the Type-S datasets.

mod common;

use grest::eval::experiments::table3_centrality;

fn main() {
    let cfg = common::bench_config();
    let js: Vec<usize> = if cfg.t_override.is_some() { vec![50, 200] } else { vec![100, 1000] };
    println!("# Table 3 — central-node identification (J = {js:?})");
    let t = common::timed("table3_centrality", || table3_centrality(&cfg, &js));
    println!("\n{}", t.render());
    let _ = t.write_csv("table3");
}
