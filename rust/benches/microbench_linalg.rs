//! Micro-benchmarks of the hot-path kernels (the §Perf instrument):
//! gemm / Gram / project-out / orthonormalize / small eigh / SpMM /
//! per-step G-REST update (native and, if artifacts exist, XLA-backed).

mod common;

use grest::linalg::{blas, eigh::eigh, mat::Mat, qr, rng::Rng};
use grest::sparse::coo::Coo;
use grest::sparse::delta::Delta;
use grest::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};

fn main() {
    let quick = std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let n: usize = if quick { 2048 } else { 16384 };
    let k = 64;
    let m = 128;
    let mut rng = Rng::new(1);
    println!("# linalg micro-benches (N={n}, K={k}, M={m})");

    let x = {
        let (q, _) = qr::thin_qr(&Mat::randn(n, k, &mut rng));
        q
    };
    let b = Mat::randn(n, m, &mut rng);

    common::micro("gram  X^T B           (NxK)'(NxM)", 800, || {
        std::hint::black_box(blas::gemm_tn(&x, &b));
    });
    common::micro("gemm  X C             (NxK)(KxM)", 800, || {
        let c = Mat::randn(k, m, &mut Rng::new(2));
        std::hint::black_box(x.matmul(&c));
    });
    common::micro("project_out (I-XX')B", 800, || {
        std::hint::black_box(blas::project_out(&x, &b));
    });
    common::micro("orthonormalize_against (panel M)", 1000, || {
        std::hint::black_box(qr::orthonormalize_against(&x, &b, 1e-8));
    });
    let t = {
        let raw = Mat::randn(k + m, k + m, &mut rng);
        let mut s = raw.clone();
        s.axpy(1.0, &raw.t());
        s
    };
    common::micro("eigh  (K+M)x(K+M)", 800, || {
        std::hint::black_box(eigh(&t));
    });

    // sparse: power-law graph SpMM
    let w = grest::graph::generators::power_law_weights(n, 2.2, 6 * n);
    let g = grest::graph::generators::chung_lu(&w, &mut rng);
    let a = g.adjacency();
    println!("# graph: {} nodes {} edges", g.n_nodes(), g.n_edges());
    common::micro("spmm  A X             (sparse NxN)(NxK)", 800, || {
        std::hint::black_box(a.matmul_dense(&x));
    });

    // per-step tracker update at bench scale
    let scenario_n = if quick { 1500 } else { 4000 };
    let w2 = grest::graph::generators::power_law_weights(scenario_n, 2.2, 5 * scenario_n);
    let g2 = grest::graph::generators::chung_lu(&w2, &mut rng);
    let a2 = g2.adjacency();
    let init = init_eigenpairs(&a2, k, 5);
    let delta = {
        let mut kb = Coo::new(scenario_n, scenario_n);
        for _ in 0..200 {
            let (u, v) = (rng.below(scenario_n), rng.below(scenario_n));
            if u != v {
                kb.push_sym(u, v, 1.0);
            }
        }
        let mut gb = Coo::new(scenario_n, 48);
        for j in 0..48 {
            for _ in 0..4 {
                gb.push(rng.below(scenario_n), j, 1.0);
            }
        }
        Delta::from_blocks(scenario_n, 48, &kb, &gb, &Coo::new(48, 48))
    };
    common::micro("G-REST3 native update (N=4000,S=48)", 2000, || {
        let mut t = GRest::new(init.clone(), SubspaceMode::Full);
        t.update(&delta).unwrap();
        std::hint::black_box(t.current().values[0]);
    });
    common::micro("G-REST-RSVD(32,32) update", 2000, || {
        let mut t = GRest::new(init.clone(), SubspaceMode::Rsvd { l: 32, p: 32 });
        t.update(&delta).unwrap();
        std::hint::black_box(t.current().values[0]);
    });

    // XLA-backed update, if artifacts are present
    if let Ok(manifest) = grest::runtime::ArtifactManifest::load_default() {
        if let Ok(phases) = grest::runtime::XlaPhases::for_problem(
            manifest,
            scenario_n + 48,
            k,
            k + 48,
        ) {
            println!("# XLA tier {:?}", phases.tier());
            let phases = std::rc::Rc::new(phases);
            // pay the one-time PJRT compile outside the timed region
            let mut warm = GRest::with_phases(init.clone(), SubspaceMode::Full, phases.clone(), 5);
            warm.update(&delta).unwrap();
            common::micro("G-REST3 XLA update (steady-state)", 2000, || {
                let mut t =
                    GRest::with_phases(init.clone(), SubspaceMode::Full, phases.clone(), 5);
                t.update(&delta).unwrap();
                std::hint::black_box(t.current().values[0]);
            });
        } else {
            println!("# no XLA tier fits this micro-bench (need n>=4048); skipped");
        }
    } else {
        println!("# artifacts not built; XLA micro-bench skipped");
    }
}
