//! Micro-benchmarks of the hot-path kernels (the §Perf instrument):
//! the six-rung GEMM ladder (naive, blocked, blocked+pool, packed,
//! packed+simd, packed+fma — the last rung opt-in and non-bitwise),
//! the f32-storage/f64-accumulate serving tier vs the f64 snapshot
//! scan, the kernel-pool dispatch overhead vs per-call scoped spawns,
//! Gram / project-out / orthonormalize, small eigh, SpMM, and the
//! per-step G-REST update (native and, if artifacts exist,
//! XLA-backed).  Every exact rung is bitwise-checked against the
//! blocked oracle before its timing is recorded.
//!
//! Emits `BENCH_linalg.json` (name → {n, seconds, gflops}) in the
//! working directory (`rust/` under `cargo bench`, which sets cwd to
//! the package root) so the perf trajectory is machine-readable from
//! this PR onward.  `GREST_BENCH_QUICK=1` shrinks every size for CI
//! smoke runs.

mod common;

use grest::linalg::blas::GemmKernel;
use grest::linalg::threads::{self, simd_level, Threads};
use grest::linalg::{blas, eigh::eigh, f32mat, mat::Mat, qr, rng::Rng, F32Mat};
use grest::sparse::coo::Coo;
use grest::sparse::delta::Delta;
use grest::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};

struct BenchRecord {
    name: String,
    n: usize,
    seconds: f64,
    gflops: f64,
}

fn record(records: &mut Vec<BenchRecord>, name: &str, n: usize, flops: f64, seconds: f64) {
    records.push(BenchRecord {
        name: name.to_string(),
        n,
        seconds,
        gflops: flops / seconds.max(1e-12) / 1e9,
    });
}

/// The seed-style reference kernel: unblocked, single-threaded i-j-k
/// triple loop.  The acceptance bar for the blocked+threaded layer is
/// ≥ 2× this at n ≥ 256.
fn naive_gemm(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for k in 0..a.cols() {
                s += a.get(i, k) * b.get(k, j);
            }
            c.set(i, j, s);
        }
    }
    c
}

fn write_json(records: &[BenchRecord]) {
    let mut out = String::from("{\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  \"{}\": {{\"n\": {}, \"seconds\": {:.6e}, \"gflops\": {:.3}}}{}\n",
            r.name,
            r.n,
            r.seconds,
            r.gflops,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    let path = "BENCH_linalg.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("# wrote {path} ({} entries)", records.len()),
        Err(e) => eprintln!("# failed to write {path}: {e}"),
    }
}

fn main() {
    let quick = std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rng = Rng::new(1);

    // ---- GEMM ladder: naive (seed-style) vs blocked vs blocked+pool
    // vs packed vs packed+simd vs packed+fma.  Rungs above naive are
    // pinned via `GemmKernel` so each record measures exactly one rung
    // (production `Auto` picks per chunk; pinning keeps the trajectory
    // comparable across PRs).  The fma rung is the one approximate rung
    // (opt-in, excluded from `Auto`); every other rung is
    // bitwise-checked against the blocked oracle below.
    println!("# simd level: {:?}", simd_level());
    let gemm_sizes: &[usize] = if quick { &[256, 512] } else { &[256, 512, 1024] };
    println!(
        "# GEMM ladder (square n×n·n×n): naive / blocked / blocked+pool / packed / packed+simd / packed+fma"
    );
    for &n in gemm_sizes {
        let a = Mat::randn(n, n, &mut rng);
        let b = Mat::randn(n, n, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        let budget = if n <= 256 { 600 } else { 1200 };
        let s = common::micro_secs(&format!("gemm naive        n={n}"), budget, || {
            std::hint::black_box(naive_gemm(&a, &b));
        });
        record(&mut records, &format!("gemm_naive_{n}"), n, flops, s);
        let mut c = Mat::zeros(n, n);
        let rungs = [
            ("gemm blocked 1t  ", "gemm_blocked_1t", Threads::SINGLE, GemmKernel::Blocked),
            ("gemm blocked pool", "gemm_blocked_mt", Threads::AUTO, GemmKernel::Blocked),
            ("gemm packed  1t  ", "gemm_packed_1t", Threads::SINGLE, GemmKernel::Packed),
            ("gemm packed  pool", "gemm_packed_mt", Threads::AUTO, GemmKernel::Packed),
            ("gemm simd    1t  ", "gemm_simd_1t", Threads::SINGLE, GemmKernel::PackedSimd),
            ("gemm simd    pool", "gemm_simd_mt", Threads::AUTO, GemmKernel::PackedSimd),
            ("gemm fma     1t  ", "gemm_fma_1t", Threads::SINGLE, GemmKernel::PackedFma),
            ("gemm fma     pool", "gemm_fma_mt", Threads::AUTO, GemmKernel::PackedFma),
        ];
        for (label, name, threads, kernel) in rungs {
            let s = common::micro_secs(&format!("{label} n={n}"), budget, || {
                c.reset(n, n);
                blas::gemm_acc_with_kernel(&mut c, &a, &b, 1.0, threads, kernel);
                std::hint::black_box(c.get(0, 0));
            });
            record(&mut records, &format!("{name}_{n}"), n, flops, s);
        }
        // bitwise gate over every exact rung (fma is exempt: it is the
        // documented approximate rung)
        let mut oracle = Mat::zeros(n, n);
        blas::gemm_acc_with_kernel(&mut oracle, &a, &b, 1.0, Threads::SINGLE, GemmKernel::Blocked);
        let exact =
            [GemmKernel::Blocked, GemmKernel::Packed, GemmKernel::PackedSimd, GemmKernel::Auto];
        for kernel in exact {
            for threads in [Threads::SINGLE, Threads::AUTO] {
                c.reset(n, n);
                blas::gemm_acc_with_kernel(&mut c, &a, &b, 1.0, threads, kernel);
                assert!(
                    c.as_slice()
                        .iter()
                        .zip(oracle.as_slice())
                        .all(|(x, y)| x.to_bits() == y.to_bits()),
                    "exact rung {kernel:?} ({threads:?}) diverged from the blocked oracle at n={n}"
                );
            }
        }
        println!("# bitwise: all exact rungs identical at n={n}");
    }

    // ---- dispatch overhead: parked-pool handoff vs per-call scoped
    // spawns, on parts tiny enough that the work itself is noise.  This
    // pair is the measurement behind the `PAR_MIN_FLOPS` recalibration
    // in `linalg::threads` (pool handoff is a mutex/condvar wake; the
    // scoped baseline pays a full spawn+join per part).
    println!("# dispatch overhead (8 tiny parts): pool handoff vs scoped spawn");
    let n_parts = 8usize;
    let mut slabs = vec![vec![0.0f64; 64]; n_parts];
    let tiny_flops = (n_parts * 64) as f64;
    let s = common::micro_secs("dispatch pool   (8 tiny parts)", 400, || {
        let parts: Vec<&mut Vec<f64>> = slabs.iter_mut().collect();
        threads::kernel_pool().run(parts, |buf: &mut Vec<f64>| {
            for v in buf.iter_mut() {
                *v += 1.0;
            }
        });
    });
    record(&mut records, "dispatch_pool_smallk", n_parts, tiny_flops, s);
    let s = common::micro_secs("dispatch scoped (8 tiny parts)", 400, || {
        let parts: Vec<&mut Vec<f64>> = slabs.iter_mut().collect();
        threads::run_scoped_baseline(parts, |buf: &mut Vec<f64>| {
            for v in buf.iter_mut() {
                *v += 1.0;
            }
        });
    });
    record(&mut records, "dispatch_scoped_smallk", n_parts, tiny_flops, s);

    // ---- panel-shaped kernels at tracker scale
    let n: usize = if quick { 2048 } else { 16384 };
    let k = 64;
    let m = 128;
    println!("# panel kernels (N={n}, K={k}, M={m})");

    let x = {
        let (q, _) = qr::thin_qr(&Mat::randn(n, k, &mut rng));
        q
    };
    let b = Mat::randn(n, m, &mut rng);

    let s = common::micro_secs("gram  X^T B           (NxK)'(NxM)", 800, || {
        std::hint::black_box(blas::gemm_tn(&x, &b));
    });
    record(&mut records, "gram_xtb", n, 2.0 * (n * k * m) as f64, s);
    let s = common::micro_secs("syrk  sym(B^T B)      (NxM)'(NxM)", 800, || {
        std::hint::black_box(blas::syrk_tn(&b, &b));
    });
    record(&mut records, "syrk_btb", n, (n * m * (m + 1)) as f64, s);
    let c64 = Mat::randn(k, m, &mut rng);
    let s = common::micro_secs("gemm  X C             (NxK)(KxM)", 800, || {
        std::hint::black_box(x.matmul(&c64));
    });
    record(&mut records, "gemm_xc", n, 2.0 * (n * k * m) as f64, s);
    let s = common::micro_secs("project_out (I-XX')B", 800, || {
        std::hint::black_box(blas::project_out(&x, &b));
    });
    record(&mut records, "project_out", n, 4.0 * (n * k * m) as f64, s);
    let s = common::micro_secs("orthonormalize_against (panel M)", 1000, || {
        std::hint::black_box(qr::orthonormalize_against(&x, &b, 1e-8));
    });
    record(
        &mut records,
        "orthonormalize_against",
        n,
        2.0 * (2 * n * k * m + n * m * m + 2 * n * m * m) as f64,
        s,
    );
    let t = {
        let raw = Mat::randn(k + m, k + m, &mut rng);
        let mut s = raw.clone();
        s.axpy(1.0, &raw.t());
        s
    };
    let s = common::micro_secs("eigh  (K+M)x(K+M)", 800, || {
        std::hint::black_box(eigh(&t));
    });
    record(&mut records, "eigh_small", k + m, 9.0 * ((k + m) as f64).powi(3), s);

    // ---- serving tier: f64 snapshot scan vs f32-storage/f64-accumulate
    // panel.  Cosine sweep = the QueryEngine `similar_to` hot loop
    // (dot + row norm per row); gemv = one dense panel-vector product.
    // The f32 tier halves bytes moved and reads rows contiguously.
    println!("# serving tier (N={n}, K={k}): f64 snapshot scan vs f32 panel");
    let panel = F32Mat::from_mat(&x);
    let qrow: Vec<f64> = (0..k).map(|j| x.get(0, j)).collect();
    let mut q32 = Vec::new();
    f32mat::demote_into(&qrow, &mut q32);
    let serve_flops = (4 * n * k) as f64;
    let s = common::micro_secs("cosine scan f64 (snapshot)", 600, || {
        let mut best = (0usize, f64::MIN);
        for i in 1..n {
            let mut dot = 0.0;
            let mut nn = 0.0;
            for (j, &qj) in qrow.iter().enumerate() {
                let v = x.get(i, j);
                dot += qj * v;
                nn += v * v;
            }
            let sim = if nn > 0.0 { dot / nn.sqrt() } else { 0.0 };
            if sim > best.1 {
                best = (i, sim);
            }
        }
        std::hint::black_box(best);
    });
    record(&mut records, "serve_f64_cosine", n, serve_flops, s);
    let s = common::micro_secs("cosine scan f32 (panel)   ", 600, || {
        let mut best = (0usize, f64::MIN);
        for i in 1..n {
            let (dot, nn) = f32mat::dot_norm2_f32(&q32, panel.row(i));
            let sim = if nn > 0.0 { dot / nn.sqrt() } else { 0.0 };
            if sim > best.1 {
                best = (i, sim);
            }
        }
        std::hint::black_box(best);
    });
    record(&mut records, "serve_f32_cosine", n, serve_flops, s);
    let xv: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
    let mut xv32 = Vec::new();
    f32mat::demote_into(&xv, &mut xv32);
    let gemv_flops = (2 * n * k) as f64;
    let s = common::micro_secs("gemv f64 (column-major)   ", 600, || {
        std::hint::black_box(blas::gemv(&x, &xv));
    });
    record(&mut records, "serve_f64_gemv", n, gemv_flops, s);
    let s = common::micro_secs("gemv f32 (row-major panel)", 600, || {
        std::hint::black_box(f32mat::gemv_f32(&panel, &xv32));
    });
    record(&mut records, "serve_f32_gemv", n, gemv_flops, s);

    // sparse: power-law graph SpMM
    let w = grest::graph::generators::power_law_weights(n, 2.2, 6 * n);
    let g = grest::graph::generators::chung_lu(&w, &mut rng);
    let a = g.adjacency();
    println!("# graph: {} nodes {} edges", g.n_nodes(), g.n_edges());
    let s = common::micro_secs("spmm  A X             (sparse NxN)(NxK)", 800, || {
        std::hint::black_box(a.matmul_dense(&x));
    });
    record(&mut records, "spmm_ax", n, 2.0 * (a.nnz() * k) as f64, s);

    // per-step tracker update at bench scale
    let scenario_n = if quick { 1500 } else { 4000 };
    let w2 = grest::graph::generators::power_law_weights(scenario_n, 2.2, 5 * scenario_n);
    let g2 = grest::graph::generators::chung_lu(&w2, &mut rng);
    let a2 = g2.adjacency();
    let init = init_eigenpairs(&a2, k, 5);
    let delta = {
        let mut kb = Coo::new(scenario_n, scenario_n);
        for _ in 0..200 {
            let (u, v) = (rng.below(scenario_n), rng.below(scenario_n));
            if u != v {
                kb.push_sym(u, v, 1.0);
            }
        }
        let mut gb = Coo::new(scenario_n, 48);
        for j in 0..48 {
            for _ in 0..4 {
                gb.push(rng.below(scenario_n), j, 1.0);
            }
        }
        Delta::from_blocks(scenario_n, 48, &kb, &gb, &Coo::new(48, 48))
    };
    let mut step_flops = 0u64;
    let s = common::micro_secs("G-REST3 native update 1t", 1500, || {
        let mut t = GRest::with_threads(init.clone(), SubspaceMode::Full, Threads::SINGLE);
        t.update(&delta).unwrap();
        step_flops = t.last_step_flops();
        std::hint::black_box(t.current().values[0]);
    });
    record(&mut records, "grest3_update_1t", scenario_n, step_flops as f64, s);
    let s = common::micro_secs("G-REST3 native update auto", 1500, || {
        let mut t = GRest::with_threads(init.clone(), SubspaceMode::Full, Threads::AUTO);
        t.update(&delta).unwrap();
        std::hint::black_box(t.current().values[0]);
    });
    record(&mut records, "grest3_update_mt", scenario_n, step_flops as f64, s);
    let mut rsvd_flops = 0u64;
    let s = common::micro_secs("G-REST-RSVD(32,32) update", 1500, || {
        let mut t = GRest::new(init.clone(), SubspaceMode::Rsvd { l: 32, p: 32 });
        t.update(&delta).unwrap();
        rsvd_flops = t.last_step_flops();
        std::hint::black_box(t.current().values[0]);
    });
    record(&mut records, "grest_rsvd_update", scenario_n, rsvd_flops as f64, s);

    // XLA-backed update, if artifacts are present (needs the `xla` feature)
    if let Ok(manifest) = grest::runtime::ArtifactManifest::load_default() {
        match grest::runtime::XlaPhases::for_problem(manifest, scenario_n + 48, k, k + 48) {
            Ok(phases) => {
                println!("# XLA tier {:?}", phases.tier());
                let phases = std::rc::Rc::new(phases);
                // pay the one-time PJRT compile outside the timed region
                let mut warm =
                    GRest::with_phases(init.clone(), SubspaceMode::Full, phases.clone(), 5);
                warm.update(&delta).unwrap();
                let s = common::micro_secs("G-REST3 XLA update (steady-state)", 2000, || {
                    let mut t =
                        GRest::with_phases(init.clone(), SubspaceMode::Full, phases.clone(), 5);
                    t.update(&delta).unwrap();
                    std::hint::black_box(t.current().values[0]);
                });
                record(&mut records, "grest3_update_xla", scenario_n, step_flops as f64, s);
            }
            Err(e) => println!("# XLA micro-bench skipped: {e}"),
        }
    } else {
        println!("# artifacts not built; XLA micro-bench skipped");
    }

    // ---- speedup summary + JSON
    let get = |records: &[BenchRecord], name: &str| {
        records
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.seconds)
            .unwrap_or(f64::NAN)
    };
    for &n in gemm_sizes {
        let naive = get(&records, &format!("gemm_naive_{n}"));
        let blocked_mt = get(&records, &format!("gemm_blocked_mt_{n}"));
        let packed_mt = get(&records, &format!("gemm_packed_mt_{n}"));
        let simd_mt = get(&records, &format!("gemm_simd_mt_{n}"));
        println!(
            "# speedup vs naive @ n={n}: blocked+pool {:.2}x, packed+pool {:.2}x, simd+pool {:.2}x",
            naive / blocked_mt,
            naive / packed_mt,
            naive / simd_mt
        );
        let packed_1t = get(&records, &format!("gemm_packed_1t_{n}"));
        let simd_1t = get(&records, &format!("gemm_simd_1t_{n}"));
        println!(
            "# simd vs packed scalar @ n={n}: {:.2}x (1t), {:.2}x (pool)",
            packed_1t / simd_1t,
            packed_mt / simd_mt
        );
    }
    let f64_cos = get(&records, "serve_f64_cosine");
    let f32_cos = get(&records, "serve_f32_cosine");
    let f64_gemv = get(&records, "serve_f64_gemv");
    let f32_gemv = get(&records, "serve_f32_gemv");
    println!(
        "# serving tier f32 vs f64: cosine {:.2}x, gemv {:.2}x",
        f64_cos / f32_cos,
        f64_gemv / f32_gemv
    );
    let pool = get(&records, "dispatch_pool_smallk");
    let scoped = get(&records, "dispatch_scoped_smallk");
    println!(
        "# dispatch overhead (8 tiny parts): pool {:.1} us vs scoped {:.1} us ({})",
        pool * 1e6,
        scoped * 1e6,
        if pool < scoped { "pool below scoped" } else { "scoped below pool" }
    );
    write_json(&records);
}
