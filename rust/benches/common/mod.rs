#![allow(dead_code)]
//! Shared mini-bench harness (criterion is unavailable offline): each
//! bench target regenerates one paper table/figure, printing the same
//! rows/series the paper reports plus wall-clock, honoring
//! `GREST_BENCH_QUICK=1` for smoke runs.

use grest::eval::experiments::ExpConfig;

/// Config for bench runs: quick if requested via env, paper-scale
/// otherwise.
pub fn bench_config() -> ExpConfig {
    if std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1") {
        ExpConfig::quick()
    } else {
        ExpConfig::paper()
    }
}

/// Time a closure, print a bench-style line, return the result.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    println!(
        "bench {label:<28} ... {:>10.3}s",
        t0.elapsed().as_secs_f64()
    );
    out
}

/// Micro-bench: run `f` repeatedly for ~`budget_ms`, report mean time.
pub fn micro(label: &str, budget_ms: u64, f: impl FnMut()) {
    micro_secs(label, budget_ms, f);
}

/// [`micro`] that also returns the mean seconds per iteration (the raw
/// number behind `BENCH_linalg.json`).
pub fn micro_secs(label: &str, budget_ms: u64, mut f: impl FnMut()) -> f64 {
    // warmup
    f();
    let budget = std::time::Duration::from_millis(budget_ms);
    let t0 = std::time::Instant::now();
    let mut iters = 0u64;
    while t0.elapsed() < budget {
        f();
        iters += 1;
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let unit = if per < 1e-3 {
        format!("{:.1} us", per * 1e6)
    } else if per < 1.0 {
        format!("{:.3} ms", per * 1e3)
    } else {
        format!("{per:.3} s")
    };
    println!("micro {label:<40} {unit:>12}/iter  ({iters} iters)");
    per
}
