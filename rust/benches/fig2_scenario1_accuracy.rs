//! Bench: reproduce paper Fig. 2 — eigenvector approximation accuracy on
//! dynamic graphs built from static (Type-S) datasets (Scenario 1).
//! Prints (a) time-averaged ψ for the three leading eigenvectors and
//! (b) the mean-ψ-vs-t series, per dataset per tracker.

mod common;

use grest::eval::experiments::figure_accuracy_runtime;
use grest::graph::datasets::Kind;

fn main() {
    let cfg = common::bench_config();
    println!("# Fig. 2 — Scenario 1 accuracy (K={}, angles over {}, MC={})", cfg.k, cfg.angles_k, cfg.mc);
    let (_, ta, tb, _) = common::timed("fig2_scenario1_accuracy", || {
        figure_accuracy_runtime(Kind::Static, &cfg)
    });
    println!("\n## Fig. 2(a): time-averaged psi, leading 3 eigenvectors\n{}", ta.render());
    println!("## Fig. 2(b): mean psi over leading {} vs t\n{}", cfg.angles_k, tb.render());
    let _ = ta.write_csv("fig2_a");
    let _ = tb.write_csv("fig2_b");
}
