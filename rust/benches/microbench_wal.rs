//! Durability-tier micro-benchmarks: WAL append/group-fsync throughput,
//! fsync latency, checkpoint store cost, and recovery/replay time as a
//! function of WAL length.
//!
//! Append throughput is measured over both backends — [`Memory`] (pure
//! framing + CRC cost) and [`FileBackend`] (real `O_APPEND` writes and
//! `fdatasync`) — so the fsync share of the batch budget is visible as
//! the gap between the two.  Recovery drives the *real* spawn recipe
//! (checkpoint load → WAL scan → tenant replay through the normal flush
//! path), so the reported seconds are what a tenant respawn actually
//! pays.
//!
//! Emits `BENCH_wal.json` (name → {n, seconds}) next to the other
//! `BENCH_*.json` files.  `GREST_BENCH_QUICK=1` shrinks the ladders for
//! CI smoke runs.

use grest::coordinator::durability::backend::{FileBackend, Memory, StorageBackend};
use grest::coordinator::durability::checkpoint::Checkpoint;
use grest::coordinator::durability::recover::{self, Recovered};
use grest::coordinator::durability::wal::Wal;
use grest::coordinator::metrics::Metrics;
use grest::coordinator::snapshot::{EmbeddingSnapshot, PublishStamp, SnapshotStore};
use grest::coordinator::tenant::{TenantBudget, TenantCmd, TenantState};
use grest::coordinator::BatchPolicy;
use grest::graph::stream::{DeltaBuilder, GraphEvent, IdMap};
use grest::linalg::rng::Rng;
use grest::tracking::spec::TrackerSpec;
use grest::tracking::traits::init_eigenpairs;
use std::sync::Arc;
use std::time::Instant;

const K: usize = 8;
const SEED: u64 = 5;

struct BenchRecord {
    name: String,
    n: usize,
    seconds: f64,
}

fn record(records: &mut Vec<BenchRecord>, name: &str, n: usize, seconds: f64) {
    records.push(BenchRecord { name: name.to_string(), n, seconds });
}

fn write_json(records: &[BenchRecord]) {
    let mut out = String::from("{\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  \"{}\": {{\"n\": {}, \"seconds\": {:.6e}}}{}\n",
            r.name,
            r.n,
            r.seconds,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    let path = "BENCH_wal.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("# wrote {path} ({} entries)", records.len()),
        Err(e) => eprintln!("# failed to write {path}: {e}"),
    }
}

fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("grest-bench-wal-{tag}-{}", std::process::id()))
}

// ---------------------------------------------------------------------
// append throughput + group-fsync latency

/// Append `total` events in `batch`-sized group commits (events frame +
/// commit frame + one sync per batch); returns (seconds, bytes written,
/// per-sync latencies).
fn run_append(
    backend: Box<dyn StorageBackend>,
    total: usize,
    batch: usize,
) -> (f64, u64, Vec<f64>) {
    let (mut wal, _) = Wal::open(backend, 0).expect("open wal");
    let events: Vec<GraphEvent> =
        (0..batch as u64).map(|i| GraphEvent::AddEdge(i, i + 1)).collect();
    let mut bytes = 0u64;
    let mut sync_lat = Vec::with_capacity(total / batch + 1);
    let t0 = Instant::now();
    let mut version = 0u64;
    let mut done = 0;
    while done < total {
        wal.append_events(&events);
        version += 1;
        wal.append_commit(version);
        bytes += wal.buffered_len() as u64;
        let s0 = Instant::now();
        wal.sync().expect("sync");
        sync_lat.push(s0.elapsed().as_secs_f64());
        done += batch;
    }
    (t0.elapsed().as_secs_f64(), bytes, sync_lat)
}

fn percentile(sorted: &[f64], p: usize) -> f64 {
    sorted[(sorted.len() * p / 100).min(sorted.len() - 1)]
}

fn bench_append(records: &mut Vec<BenchRecord>, quick: bool) {
    let total = if quick { 20_000 } else { 200_000 };
    let batches: &[usize] = if quick { &[16, 256] } else { &[16, 256, 4096] };
    for &batch in batches {
        let (mem_secs, mem_bytes, _) = run_append(Box::new(Memory::new()), total, batch);
        let path = temp_path(&format!("append-b{batch}"));
        let (file_secs, file_bytes, mut lat) =
            run_append(Box::new(FileBackend::new(&path)), total, batch);
        let _ = std::fs::remove_file(path);
        lat.sort_by(f64::total_cmp);
        let (p50, p95) = (percentile(&lat, 50), percentile(&lat, 95));
        println!(
            "# append b{batch:<5} mem {:>9.0} ev/s ({:>6.1} MB/s) | file {:>9.0} ev/s \
             ({:>6.1} MB/s) fsync p50 {:>7.1}us p95 {:>7.1}us",
            total as f64 / mem_secs,
            mem_bytes as f64 / mem_secs / 1e6,
            total as f64 / file_secs,
            file_bytes as f64 / file_secs / 1e6,
            p50 * 1e6,
            p95 * 1e6,
        );
        record(records, &format!("wal_append_mem_b{batch}"), total, mem_secs);
        record(records, &format!("wal_append_file_b{batch}"), total, file_secs);
        record(records, &format!("wal_fsync_file_b{batch}_p95"), lat.len(), p95);
    }
}

// ---------------------------------------------------------------------
// checkpoint store cost

fn bench_checkpoint(records: &mut Vec<BenchRecord>, quick: bool) {
    let sizes: &[usize] = if quick { &[1_000] } else { &[1_000, 10_000] };
    for &n in sizes {
        let mut rng = Rng::new(SEED);
        let g = grest::graph::generators::erdos_renyi(n, 8.0 / n as f64, &mut rng);
        let a0 = g.adjacency();
        let init = init_eigenpairs(&a0, K, SEED);
        let tracker =
            TrackerSpec::default().build_seeded_send(&a0, &init, SEED).expect("tracker");
        let ckpt = Checkpoint {
            next_seq: 1,
            version: 1,
            wall_us: 0,
            pairs: init,
            ids: IdMap::identity(n).externals().to_vec(),
            adjacency: a0,
            tracker: tracker.save_state().expect("save_state"),
        };
        let path = temp_path(&format!("ckpt-n{n}"));
        let mut backend = FileBackend::new(&path);
        let iters = if quick { 5 } else { 20 };
        let t0 = Instant::now();
        for _ in 0..iters {
            ckpt.store(&mut backend).expect("store");
        }
        let secs = t0.elapsed().as_secs_f64() / iters as f64;
        let bytes = ckpt.encode().len();
        drop(backend);
        let _ = std::fs::remove_file(path);
        println!(
            "# checkpoint n{n:<6} {:>8.0} KB image, store {:>8.2} ms ({:>6.1} MB/s)",
            bytes as f64 / 1e3,
            secs * 1e3,
            bytes as f64 / secs / 1e6,
        );
        record(records, &format!("ckpt_store_n{n}"), n, secs);
    }
}

// ---------------------------------------------------------------------
// recovery/replay time vs WAL length

/// The spawn recipe over injectable backends (mirrors
/// `coordinator/service.rs::build_state`); replay runs through the
/// normal tenant flush path.
fn spawn_tenant(
    wal: Box<dyn StorageBackend>,
    ckpt: Box<dyn StorageBackend>,
    n0: usize,
) -> TenantState {
    let mut rng = Rng::new(SEED);
    let g = grest::graph::generators::erdos_renyi(n0, 8.0 / n0 as f64, &mut rng);
    let a0 = g.adjacency();
    let init = init_eigenpairs(&a0, K, SEED);
    let mut tracker =
        TrackerSpec::default().build_seeded_send(&a0, &init, SEED).expect("tracker");
    let store = SnapshotStore::new(EmbeddingSnapshot {
        version: 0,
        n_nodes: a0.n_rows,
        pairs: init.clone(),
        ids: Arc::new(IdMap::identity(a0.n_rows)),
        published_at: PublishStamp::now(),
    });
    let Recovered { checkpoint, tail, wal, ckpt_backend, .. } =
        recover::load(wal, ckpt).expect("recover");
    let mut state = match checkpoint {
        Some(c) => {
            tracker.restore_state(c.tracker).expect("restore");
            let builder = DeltaBuilder::from_committed(&c.adjacency, c.ids.clone());
            let mut st = TenantState::new(
                tracker,
                builder,
                c.adjacency.clone(),
                BatchPolicy::ByCount(1),
                store.clone(),
                Metrics::new(),
                TenantBudget::default(),
            );
            st.restore_version(c.version);
            st
        }
        None => TenantState::new(
            tracker,
            DeltaBuilder::from_graph(g),
            a0,
            BatchPolicy::ByCount(1),
            store,
            Metrics::new(),
            TenantBudget::default(),
        ),
    };
    state.replay(&tail).expect("replay");
    state.attach_durability(grest::coordinator::durability::TenantDurability::new(
        wal,
        ckpt_backend,
        usize::MAX, // replay cost only: never checkpoint
    ));
    state
}

fn bench_recovery(records: &mut Vec<BenchRecord>, quick: bool) {
    let n0 = 300;
    let walls: &[usize] = if quick { &[16, 64] } else { &[16, 64, 256] };
    for &batches in walls {
        let wal_mem = Memory::new();
        let ckpt_mem = Memory::new();
        {
            let mut live =
                spawn_tenant(Box::new(wal_mem.clone()), Box::new(ckpt_mem.clone()), n0);
            let mut rng = Rng::new(99);
            for b in 0..batches as u64 {
                let mut evs = vec![GraphEvent::AddEdge(rng.below(n0) as u64, 10_000 + b)];
                for _ in 0..7 {
                    evs.push(GraphEvent::AddEdge(
                        rng.below(n0) as u64,
                        rng.below(n0 + 64) as u64,
                    ));
                }
                let _ = live.apply(TenantCmd::Events(evs));
            }
            assert_eq!(live.version(), batches as u64);
        }
        wal_mem.crash();
        let t0 = Instant::now();
        let rec = spawn_tenant(Box::new(wal_mem.clone()), Box::new(ckpt_mem.clone()), n0);
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(rec.version(), batches as u64, "recovery must replay every batch");
        println!(
            "# recover {batches:>4}-batch wal: {:>8.2} ms ({:>7.2} ms/batch)",
            secs * 1e3,
            secs * 1e3 / batches as f64,
        );
        record(records, &format!("recover_replay_w{batches}"), batches, secs);
    }
}

fn main() {
    let quick = std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut records: Vec<BenchRecord> = Vec::new();
    bench_append(&mut records, quick);
    bench_checkpoint(&mut records, quick);
    bench_recovery(&mut records, quick);
    write_json(&records);
}
