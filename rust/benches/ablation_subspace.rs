//! Ablation bench: the design choices DESIGN.md calls out, isolated on
//! one expansion-heavy workload (CM-Collab-scaled, Scenario 1):
//!
//!  A1  subspace content (Table 1 of the paper): X̄-only RR vs +ΔX̄_K
//!      (G-REST₂) vs +Δ₂ (G-REST₃) vs RSVD-compressed Δ₂.
//!  A2  rank-K Ā approximation in Eq. (13): G-REST₃ as shipped
//!      (Zᵀ(X̄ΛX̄ᵀ)Z) vs the exact ZᵀĀZ (requires retaining Ā — the
//!      memory trade-off the paper's Remark 1 discusses).
//!  A3  projection hygiene: single vs double (BCGS2) project-out pass
//!      in the basis construction.
//!
//! Prints mean ψ (leading 8) and per-variant time.

mod common;

use grest::eval::angle::mean_angle;
use grest::graph::{generators, scenario::scenario1_from_static};
use grest::linalg::mat::Padded;
use grest::linalg::workspace::StepWorkspace;
use grest::linalg::{blas, mat::Mat, rng::Rng};
use grest::sparse::csr::Csr;
use grest::tracking::grest::{DensePhases, NativePhases};
use grest::tracking::traits::{apply_delta, init_eigenpairs};
use grest::tracking::{EigTracker, GRest, SubspaceMode};

/// A2: exact-Ā variant of G-REST₃ — retains the adjacency and forms
/// ZᵀÂZ directly (instead of the rank-K approximation of Eq. 13).
struct ExactAGrest {
    a: Csr,
    state: grest::tracking::EigenPairs,
    ws: StepWorkspace,
}

impl EigTracker for ExactAGrest {
    fn descriptor(&self) -> grest::tracking::TrackerSpec {
        grest::tracking::TrackerSpec::custom("G-REST3-exactA")
    }
    fn update(&mut self, delta: &grest::Delta) -> anyhow::Result<()> {
        let phases = NativePhases::default();
        let k = self.state.k();
        self.a = apply_delta(&self.a, delta);
        let xbar = self.state.vectors.pad_rows(delta.s_new);
        let dxk = delta.mul_padded(&self.state.vectors);
        let panel = if delta.s_new == 0 { dxk.clone() } else { dxk.hcat(&delta.d2_dense()) };
        let q = phases.build_basis(Padded::from(&xbar), panel, &mut self.ws);
        // exact T = Zᵀ Â Z with Z = [X̄ Q] (Â already includes Δ)
        let z = xbar.hcat(&q);
        let az = self.a.matmul_dense(&z);
        let t = z.t_matmul(&az);
        let e = grest::linalg::eigh::eigh(&t);
        let order = e.leading_by_magnitude(k);
        let mut f = Mat::zeros(z.cols(), k);
        let mut vals = Vec::with_capacity(k);
        for (c, &idx) in order.iter().enumerate() {
            vals.push(e.values[idx]);
            for i in 0..z.cols() {
                f.set(i, c, e.vectors.get(i, idx));
            }
        }
        let new_vecs = z.matmul(&f);
        self.state = grest::tracking::EigenPairs { values: vals, vectors: new_vecs };
        Ok(())
    }
    fn current(&self) -> &grest::tracking::EigenPairs {
        &self.state
    }
}

/// A3: single-pass (non-BCGS2) basis construction.
struct SinglePassPhases;

impl DensePhases for SinglePassPhases {
    fn build_basis(&self, xbar: Padded<'_>, panel: Mat, ws: &mut StepWorkspace) -> Mat {
        // one projection + one CholQR only
        let p = blas::project_out(xbar, &panel);
        ws.give_mat(panel);
        let g = p.t_matmul(&p);
        let (l, _keep) = grest::linalg::chol::cholesky_guarded(&g, 1e-8);
        let rinv = grest::linalg::chol::tri_inv_upper(&l.t());
        let p = p.matmul(&rinv);
        let kept: Vec<usize> = (0..p.cols())
            .filter(|&j| blas::nrm2(p.col(j)) > 0.5)
            .collect();
        let mut q = p.select_cols(&kept);
        for j in 0..q.cols() {
            let n = blas::nrm2(q.col(j));
            for e in q.col_mut(j) {
                *e /= n;
            }
        }
        q
    }
    fn form_t(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        lam: &[f64],
        dxk: &Mat,
        dq: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        NativePhases::default().form_t(xbar, q, lam, dxk, dq, ws)
    }
    fn rotate(
        &self,
        xbar: Padded<'_>,
        q: &Mat,
        f1: &Mat,
        f2: &Mat,
        ws: &mut StepWorkspace,
    ) -> Mat {
        NativePhases::default().rotate(xbar, q, f1, f2, ws)
    }
}

fn main() {
    let mut rng = Rng::new(7);
    let n = 1200;
    let k = 32;
    let w = generators::power_law_weights(n, 2.3, 5 * n);
    let g = generators::chung_lu(&w, &mut rng);
    let sc = scenario1_from_static("ablation", &g, 8);
    println!(
        "# Ablation workload: {} -> {} nodes over {} steps, K={k}",
        sc.initial.n_rows,
        sc.max_nodes(),
        sc.t_steps()
    );
    let init = init_eigenpairs(&sc.initial, k, 3);
    let reference = grest::eval::harness::reference_run(&sc, k, 9);

    let mut variants: Vec<(String, Box<dyn EigTracker>)> = vec![
        ("A1 G-REST2 (no Delta2)".into(), Box::new(GRest::new(init.clone(), SubspaceMode::Rm))),
        ("A1 G-REST3 (+Delta2)".into(), Box::new(GRest::new(init.clone(), SubspaceMode::Full))),
        (
            "A1 RSVD(16,16)".into(),
            Box::new(GRest::new(init.clone(), SubspaceMode::Rsvd { l: 16, p: 16 })),
        ),
        (
            "A2 exact-Abar (Remark 1)".into(),
            Box::new(ExactAGrest {
                a: sc.initial.clone(),
                state: init.clone(),
                ws: StepWorkspace::new(),
            }),
        ),
        (
            "A3 single-pass basis".into(),
            Box::new(GRest::with_phases(init.clone(), SubspaceMode::Full, SinglePassPhases, 3)),
        ),
    ];

    println!("{:<28} {:>12} {:>12}", "variant", "mean_psi(8)", "total_time");
    for (name, tracker) in variants.iter_mut() {
        let t0 = std::time::Instant::now();
        let mut psi_sum = 0.0;
        for (t, step) in sc.steps.iter().enumerate() {
            tracker.update(&step.delta).unwrap();
            psi_sum += mean_angle(tracker.current(), &reference.per_step[t], 8);
        }
        println!(
            "{:<28} {:>12.5} {:>11.3}s",
            name,
            psi_sum / sc.steps.len() as f64,
            t0.elapsed().as_secs_f64()
        );
    }
    println!("\n(A2 shows what the rank-K approximation of Eq. 13 costs in accuracy;");
    println!(" A3 shows the orthogonality loss of skipping the second BCGS2 pass.)");
}
