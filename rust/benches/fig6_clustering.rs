//! Bench: reproduce paper Fig. 6 — clustering performance (ARI relative
//! to the eigs reference) on synthetic SBM dynamic graphs, sweeping the
//! inter-cluster edge probability (a) and the number of clusters (b).

mod common;

use grest::eval::experiments::fig6_clustering;

fn main() {
    let cfg = common::bench_config();
    let (n, p_outs, ks): (usize, Vec<f64>, Vec<usize>) = if cfg.t_override.is_some() {
        (400, vec![0.002, 0.01], vec![2, 4])
    } else {
        (2000, vec![0.002, 0.005, 0.01, 0.02], vec![2, 4, 6, 8])
    };
    println!("# Fig. 6 — SBM clustering ARI ratio (N={n}, p_in=0.05)");
    let t = common::timed("fig6_clustering", || fig6_clustering(&cfg, n, &p_outs, &ks));
    println!("\n{}", t.render());
    let _ = t.write_csv("fig6");
}
