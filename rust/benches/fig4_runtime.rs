//! Bench: reproduce paper Fig. 4 — runtimes of all trackers (plus the
//! `eigs` baseline) on the Scenario 1 (a) and Scenario 2 (b) datasets.

mod common;

use grest::eval::experiments::figure_accuracy_runtime;
use grest::graph::datasets::Kind;

fn main() {
    let cfg = common::bench_config();
    println!("# Fig. 4 — runtimes (K={}, MC={})", cfg.k, cfg.mc);
    let (_, _, _, ta) = common::timed("fig4a_static_runtimes", || {
        figure_accuracy_runtime(Kind::Static, &cfg)
    });
    println!("\n## Fig. 4(a): Scenario 1 runtimes\n{}", ta.render());
    let _ = ta.write_csv("fig4_a");
    let (_, _, _, tb) = common::timed("fig4b_dynamic_runtimes", || {
        figure_accuracy_runtime(Kind::Dynamic, &cfg)
    });
    println!("\n## Fig. 4(b): Scenario 2 runtimes\n{}", tb.render());
    let _ = tb.write_csv("fig4_b");
}
