//! Per-step G-REST micro-bench (the §Perf instrument for the hottest
//! loop in the system): a latency ladder over n × k × batch shape,
//! expansion-heavy vs edge-only, padded-view pipeline vs the
//! materialized `pad_rows` oracle — plus a **counting global allocator**
//! that proves a warmed tracker performs **zero heap allocations** per
//! sequential update (the steady-state contract of `StepWorkspace`).
//!
//! Emits `BENCH_grest.json` (name → {n, k, s, seconds, allocs}) in the
//! working directory (`rust/` under `cargo bench`).  `GREST_BENCH_QUICK=1`
//! shrinks every size for CI smoke runs.

mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::sparse::coo::Coo;
use grest::sparse::delta::Delta;
use grest::tracking::grest::{MaterializedPhases, NativePhases};
use grest::tracking::{init_eigenpairs, EigTracker, EigenPairs, GRest, SubspaceMode};

/// Global allocator that counts every alloc/realloc — the instrument
/// behind the zero-allocation steady-state assertion.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a pure pass-through to `System` — every pointer and layout is
// forwarded unchanged, so `System`'s GlobalAlloc contract (the only
// source of allocator correctness here) is preserved verbatim.  The
// counter bump allocates nothing (a relaxed fetch_add on a static),
// which keeps the implementation reentrancy-free.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds GlobalAlloc's contract for `layout`
        // (non-zero size); it is forwarded unchanged.
        unsafe { System.alloc(layout) }
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: as in `alloc` — the caller's `layout` obligations
        // transfer directly to `System`.
        unsafe { System.alloc_zeroed(layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller guarantees `ptr` came from this allocator with
        // `layout`, and this allocator allocates via `System`, so the
        // triple is valid for `System.realloc` unchanged.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: caller guarantees `ptr`/`layout` describe a live block
        // from this allocator, which always allocates through `System`.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

struct BenchRecord {
    name: String,
    n: usize,
    k: usize,
    s: usize,
    seconds: f64,
    allocs: u64,
}

fn record(records: &mut Vec<BenchRecord>, name: &str, n: usize, k: usize, s: usize, seconds: f64) {
    records.push(BenchRecord { name: name.into(), n, k, s, seconds, allocs: 0 });
}

fn write_json(records: &[BenchRecord]) {
    let mut out = String::from("{\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  \"{}\": {{\"n\": {}, \"k\": {}, \"s\": {}, \"seconds\": {:.6e}, \"allocs\": {}}}{}\n",
            r.name,
            r.n,
            r.k,
            r.s,
            r.seconds,
            r.allocs,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    let path = "BENCH_grest.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("# wrote {path} ({} entries)", records.len()),
        Err(e) => eprintln!("# failed to write {path}: {e}"),
    }
}

/// Expansion-heavy batch: `batch` topological edges plus `s` new nodes
/// wired in with 3 edges each.
fn make_delta(n: usize, s: usize, batch: usize, seed: u64) -> Delta {
    let mut rng = Rng::new(seed);
    let mut kb = Coo::new(n, n);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..batch {
        let (u, v) = (rng.below(n), rng.below(n));
        if u != v && seen.insert((u.min(v), u.max(v))) {
            kb.push_sym(u, v, 1.0);
        }
    }
    let mut g = Coo::new(n, s);
    for j in 0..s {
        let mut used = std::collections::HashSet::new();
        for _ in 0..3 {
            let u = rng.below(n);
            if used.insert(u) {
                g.push(u, j, 1.0);
            }
        }
    }
    let c = Coo::new(s, s);
    Delta::from_blocks(n, s, &kb, &g, &c)
}

fn graph_and_init(n: usize, k: usize, rng: &mut Rng) -> EigenPairs {
    let w = grest::graph::generators::power_law_weights(n, 2.2, 5 * n);
    let a = grest::graph::generators::chung_lu(&w, rng).adjacency();
    init_eigenpairs(&a, k, 5)
}

fn main() {
    let quick = std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rng = Rng::new(1);

    // ---- latency ladder: n × k × batch, padded vs materialized,
    //      expansion-heavy vs edge-only
    let sizes: &[usize] = if quick { &[1500] } else { &[2000, 8000] };
    // k=8 probes the small-k regime where per-step cost is dominated by
    // kernel dispatch rather than flops — the case the persistent
    // kernel pool (and the recalibrated `PAR_MIN_FLOPS`) targets.
    let ks: &[usize] = if quick { &[32] } else { &[8, 32, 96] };
    let budget = if quick { 400 } else { 1200 };
    for &n in sizes {
        for &k in ks {
            let init = graph_and_init(n, k, &mut rng);
            let s = (n / 40).max(8); // expansion-heavy: ~2.5% new nodes
            let batch = n / 10;
            for (tag, delta) in [
                ("exp", make_delta(n, s, batch, 7)),
                ("edge", make_delta(n, 0, batch, 8)),
            ] {
                let label = format!("n={n} k={k} {tag}");
                // warmed steady-state timing: one long-lived tracker per
                // arm, rewound to the same state before every step
                // (reset_state reuses the buffers, so the measured body
                // is one memcpy + one warmed update — no construction,
                // no workspace growth in the timed region)
                let mut tp = GRest::with_threads(init.clone(), SubspaceMode::Full, Threads::SINGLE);
                let sp = common::micro_secs(&format!("padded      {label}"), budget, || {
                    tp.reset_state(&init);
                    tp.update(&delta).unwrap();
                    std::hint::black_box(tp.current().values[0]);
                });
                record(
                    &mut records,
                    &format!("grest3_padded_n{n}_k{k}_{tag}"),
                    n,
                    k,
                    delta.s_new,
                    sp,
                );
                let mut tm = GRest::with_phases(
                    init.clone(),
                    SubspaceMode::Full,
                    MaterializedPhases(NativePhases::new(Threads::SINGLE)),
                    0x9E57,
                );
                let sm = common::micro_secs(&format!("materialized {label}"), budget, || {
                    tm.reset_state(&init);
                    tm.update(&delta).unwrap();
                    std::hint::black_box(tm.current().values[0]);
                });
                record(&mut records, &format!("grest3_mat_n{n}_k{k}_{tag}"), n, k, delta.s_new, sm);
                println!("# padded/materialized @ {label}: {:.2}x", sm / sp);
            }
        }
    }

    // ---- bitwise check: padded pipeline == materialized oracle
    {
        let n = if quick { 600 } else { 2000 };
        let k = 32;
        let init = graph_and_init(n, k, &mut rng);
        let d = make_delta(n, n / 40, n / 10, 9);
        let mut tp = GRest::with_threads(init.clone(), SubspaceMode::Full, Threads::SINGLE);
        let mut tm = GRest::with_phases(
            init,
            SubspaceMode::Full,
            MaterializedPhases(NativePhases::new(Threads::SINGLE)),
            0x9E57,
        );
        tp.update(&d).unwrap();
        tm.update(&d).unwrap();
        assert_eq!(tp.current().values, tm.current().values, "padded values drifted");
        assert_eq!(
            tp.current().vectors.as_slice(),
            tm.current().vectors.as_slice(),
            "padded vectors drifted from the materialized oracle"
        );
        println!("# bitwise: padded pipeline == materialized oracle at n={n}");
    }

    // ---- steady-state allocation counter: a warmed tracker must not
    //      touch the heap on the sequential path
    {
        let n = if quick { 800 } else { 3000 };
        let k = if quick { 24 } else { 48 };
        let init = graph_and_init(n, k, &mut rng);
        let d_edge = make_delta(n, 0, n / 10, 10);
        let mut t = GRest::with_threads(init, SubspaceMode::Full, Threads::SINGLE);
        // warm: grow every pool buffer and settle the LIFO role mapping
        for _ in 0..3 {
            t.update(&d_edge).unwrap();
        }
        let steps = 10u64;
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..steps {
            t.update(&d_edge).unwrap();
        }
        let total = ALLOCS.load(Ordering::Relaxed) - before;
        println!("# steady-state allocations over {steps} warmed steps: {total}");
        assert_eq!(
            total, 0,
            "warmed G-REST update must be allocation-free (got {total} allocs in {steps} steps)"
        );
        records.push(BenchRecord {
            name: "steady_state_allocs_per_step".into(),
            n,
            k,
            s: 0,
            seconds: 0.0,
            allocs: total / steps,
        });
    }

    write_json(&records);
}
