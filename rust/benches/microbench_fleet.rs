//! Fleet micro-benchmarks: the shared worker pool vs thread-per-tenant.
//!
//! A tenants × ingest-rate ladder runs the same deterministic per-tenant
//! event streams twice — once as a [`Fleet`] on a fixed 4-worker pool,
//! once as dedicated pinned threads — and reports total wall-clock and
//! the client-observed p95 flush round-trip at each tenant count.  The
//! two runs must publish bitwise-identical final snapshots per tenant
//! (asserted here: pooled scheduling reorders *which tenant runs when*,
//! never what a tenant computes).
//!
//! Emits `BENCH_fleet.json` (name → {n, seconds}) next to the other
//! `BENCH_*.json` files.  `GREST_BENCH_QUICK=1` shrinks the ladder for
//! CI smoke runs.

use grest::coordinator::{
    BatchPolicy, Fleet, FleetConfig, ServiceConfig, ServiceHandle, TenantId, TrackingService,
};
use grest::graph::stream::GraphEvent;
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::linalg::ServePrecision;
use grest::tracking::TrackerSpec;

const POOL_WORKERS: usize = 4;

struct BenchRecord {
    name: String,
    n: usize,
    seconds: f64,
}

fn record(records: &mut Vec<BenchRecord>, name: &str, n: usize, seconds: f64) {
    records.push(BenchRecord { name: name.to_string(), n, seconds });
}

fn write_json(records: &[BenchRecord]) {
    let mut out = String::from("{\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  \"{}\": {{\"n\": {}, \"seconds\": {:.6e}}}{}\n",
            r.name,
            r.n,
            r.seconds,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    let path = "BENCH_fleet.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("# wrote {path} ({} entries)", records.len()),
        Err(e) => eprintln!("# failed to write {path}: {e}"),
    }
}

fn tenant_config(n: usize, k: usize, seed: u64) -> ServiceConfig {
    let mut rng = Rng::new(seed);
    ServiceConfig {
        initial: grest::graph::generators::erdos_renyi(n, 8.0 / n as f64, &mut rng),
        k,
        policy: BatchPolicy::ByCount(32),
        seed,
        tracker: TrackerSpec::parse("grest3").unwrap(),
        threads: Threads::SINGLE,
        serve_precision: ServePrecision::F64,
        durability: None,
    }
}

/// Deterministic per-tenant event stream (tenant-salted, growing id
/// space) — identical for the pooled and pinned runs.
fn event(n: usize, tenant: u64, i: u64) -> GraphEvent {
    let a = (i * 7919 + tenant * 13) % n as u64;
    if i % 10 == 9 {
        GraphEvent::RemoveEdge(a, (i * 104_729 + tenant) % n as u64)
    } else {
        let b = (i * 104_729 + tenant + 1) % (n as u64 + n as u64 / 8);
        GraphEvent::AddEdge(a, b)
    }
}

/// Round-robin ingest into every tenant with periodic synchronous
/// flushes; returns (total wall seconds, p95 flush round-trip seconds).
fn drive(handles: &[ServiceHandle], n: usize, events_per_tenant: usize) -> (f64, f64) {
    let t0 = std::time::Instant::now();
    let mut flush_lat: Vec<f64> = Vec::new();
    for i in 0..events_per_tenant as u64 {
        for (t, h) in handles.iter().enumerate() {
            h.ingest(vec![event(n, t as u64, i)]).unwrap();
        }
        if (i + 1) % 64 == 0 {
            for h in handles {
                let f0 = std::time::Instant::now();
                h.flush().unwrap();
                flush_lat.push(f0.elapsed().as_secs_f64());
            }
        }
    }
    for h in handles {
        let f0 = std::time::Instant::now();
        h.flush().unwrap();
        flush_lat.push(f0.elapsed().as_secs_f64());
    }
    let secs = t0.elapsed().as_secs_f64();
    flush_lat.sort_by(f64::total_cmp);
    let p95 = flush_lat[(flush_lat.len() * 95 / 100).min(flush_lat.len() - 1)];
    (secs, p95)
}

/// (version, eigenvalues, eigenvector data) per tenant — the bitwise
/// comparison key between the pooled and pinned runs.
fn snapshots(handles: &[ServiceHandle]) -> Vec<(u64, Vec<f64>, Vec<f64>)> {
    handles
        .iter()
        .map(|h| {
            let s = h.snapshot();
            (s.version, s.pairs.values.clone(), s.pairs.vectors.as_slice().to_vec())
        })
        .collect()
}

fn main() {
    let quick = std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut records: Vec<BenchRecord> = Vec::new();
    let (n, k, events_per_tenant) = if quick { (300, 8, 192) } else { (1_000, 16, 640) };
    let ladder: &[usize] = if quick { &[2, 4, 8] } else { &[2, 4, 8, 16] };

    for &tenants in ladder {
        // ---- pooled: `tenants` tenants share POOL_WORKERS workers
        let fleet = Fleet::new(FleetConfig { workers: POOL_WORKERS });
        for t in 0..tenants as u64 {
            fleet.spawn(TenantId(t), tenant_config(n, k, 100 + t)).unwrap();
        }
        let pooled: Vec<ServiceHandle> =
            (0..tenants as u64).map(|t| fleet.get(TenantId(t)).unwrap()).collect();
        let (pool_secs, pool_p95) = drive(&pooled, n, events_per_tenant);
        let pool_snaps = snapshots(&pooled);
        drop(pooled);
        fleet.join();

        // ---- pinned: the same streams, one dedicated thread per tenant
        let pinned_svcs: Vec<TrackingService> = (0..tenants as u64)
            .map(|t| TrackingService::spawn_pinned(tenant_config(n, k, 100 + t)).unwrap())
            .collect();
        let pinned: Vec<ServiceHandle> =
            pinned_svcs.iter().map(|s| s.handle.clone()).collect();
        let (pin_secs, pin_p95) = drive(&pinned, n, events_per_tenant);
        let pin_snaps = snapshots(&pinned);
        drop(pinned);
        for s in pinned_svcs {
            s.join();
        }

        // pooled scheduling must not change any tenant's results
        assert_eq!(
            pool_snaps, pin_snaps,
            "pooled vs pinned snapshots diverged at {tenants} tenants"
        );

        println!(
            "# {tenants:>2} tenants x {events_per_tenant} events: \
             pool({POOL_WORKERS}w) {pool_secs:>7.3}s p95_flush {:>8.1}us | \
             pinned {pin_secs:>7.3}s p95_flush {:>8.1}us",
            pool_p95 * 1e6,
            pin_p95 * 1e6,
        );
        record(&mut records, &format!("fleet_pool{POOL_WORKERS}_t{tenants}"), tenants, pool_secs);
        record(
            &mut records,
            &format!("fleet_pool{POOL_WORKERS}_t{tenants}_p95flush"),
            tenants,
            pool_p95,
        );
        record(&mut records, &format!("fleet_pinned_t{tenants}"), tenants, pin_secs);
        record(&mut records, &format!("fleet_pinned_t{tenants}_p95flush"), tenants, pin_p95);
    }

    write_json(&records);
}
