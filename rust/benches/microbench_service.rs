//! Serving-path micro-benchmarks (the §Perf instrument for the
//! lock-free query engine):
//!
//! * cached vs uncached derived-query latency at one pinned snapshot —
//!   the version-keyed memo cache should put repeated queries orders of
//!   magnitude below the first compute;
//! * ingest throughput with 0/4/16 concurrent reader threads hammering
//!   snapshot + derived queries — readers never enqueue worker
//!   commands, so throughput must not collapse with reader count.
//!
//! Emits `BENCH_service.json` (name → {n, seconds}) next to
//! `BENCH_linalg.json` / `BENCH_sparse.json`.  `GREST_BENCH_QUICK=1`
//! shrinks every size for CI smoke runs.

mod common;

use grest::coordinator::metrics::Metrics;
use grest::coordinator::{BatchPolicy, QueryEngine, ServiceConfig, TrackingService};
use grest::graph::stream::GraphEvent;
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::linalg::ServePrecision;
use grest::tracking::TrackerSpec;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

struct BenchRecord {
    name: String,
    n: usize,
    seconds: f64,
}

fn record(records: &mut Vec<BenchRecord>, name: &str, n: usize, seconds: f64) {
    records.push(BenchRecord { name: name.to_string(), n, seconds });
}

fn write_json(records: &[BenchRecord]) {
    let mut out = String::from("{\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "  \"{}\": {{\"n\": {}, \"seconds\": {:.6e}}}{}\n",
            r.name,
            r.n,
            r.seconds,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("}\n");
    let path = "BENCH_service.json";
    match std::fs::write(path, &out) {
        Ok(()) => println!("# wrote {path} ({} entries)", records.len()),
        Err(e) => eprintln!("# failed to write {path}: {e}"),
    }
}

fn spawn_service(n: usize, k: usize, batch: usize, seed: u64) -> TrackingService {
    let mut rng = Rng::new(seed);
    let g = grest::graph::generators::erdos_renyi(n, 8.0 / n as f64, &mut rng);
    TrackingService::spawn(ServiceConfig {
        initial: g,
        k,
        policy: BatchPolicy::ByCount(batch),
        seed,
        tracker: TrackerSpec::parse("grest3").unwrap(),
        threads: Threads::SINGLE,
        serve_precision: ServePrecision::F64,
        durability: None,
    })
    .unwrap()
}

/// Deterministic mixed event stream over a growing id space.
fn event(n: usize, i: u64) -> GraphEvent {
    let a = (i * 7919) % n as u64;
    if i % 10 == 9 {
        GraphEvent::RemoveEdge(a, (i * 104_729 + 1) % n as u64)
    } else {
        // ~1 in 8 events touches a not-yet-seen id (expansion)
        let b = (i * 104_729 + 1) % (n as u64 + n as u64 / 8);
        GraphEvent::AddEdge(a, b)
    }
}

fn main() {
    let quick = std::env::var("GREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut records: Vec<BenchRecord> = Vec::new();
    let (n, k, n_events) = if quick { (400, 8, 1_500) } else { (2_000, 16, 8_000) };

    // ---- cached vs uncached derived-query latency at one snapshot
    let svc = spawn_service(n, k, 64, 1);
    let h = svc.handle.clone();
    for i in 0..(n_events as u64 / 4) {
        h.ingest(vec![event(n, i)]).unwrap();
    }
    h.flush().unwrap();
    let snap = h.snapshot();
    println!("# service graph: {} nodes, snapshot v{}", snap.n_nodes, snap.version);
    let eng = h.query_engine();
    let _ = eng.central_nodes(&snap, 20); // warm the slots under test
    let _ = eng.clusters(&snap, 4);
    let s = common::micro_secs("central-nodes cached   ", 300, || {
        std::hint::black_box(eng.central_nodes(&snap, 20));
    });
    record(&mut records, "query_central_cached", n, s);
    let s = common::micro_secs("central-nodes uncached ", 300, || {
        // a fresh engine per call: every query recomputes from the snapshot
        let cold = QueryEngine::new(1, Threads::SINGLE, Metrics::new());
        std::hint::black_box(cold.central_nodes(&snap, 20));
    });
    record(&mut records, "query_central_uncached", n, s);
    let s = common::micro_secs("clusters k=4 cached    ", 300, || {
        std::hint::black_box(eng.clusters(&snap, 4));
    });
    record(&mut records, "query_clusters_cached", n, s);
    let s = common::micro_secs("clusters k=4 uncached  ", 1000, || {
        let cold = QueryEngine::new(1, Threads::SINGLE, Metrics::new());
        std::hint::black_box(cold.clusters(&snap, 4));
    });
    record(&mut records, "query_clusters_uncached", n, s);
    let cached = records.iter().find(|r| r.name == "query_clusters_cached").unwrap().seconds;
    let uncached =
        records.iter().find(|r| r.name == "query_clusters_uncached").unwrap().seconds;
    println!("# memo-cache speedup on clusters: {:.0}x", uncached / cached);
    svc.join();

    // ---- ingest throughput with 0/4/16 concurrent readers
    for &n_readers in &[0usize, 4, 16] {
        let svc = spawn_service(n, k, 32, 2);
        let h = svc.handle.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = vec![];
        for r in 0..n_readers as u64 {
            let h = h.clone();
            let stop = stop.clone();
            readers.push(std::thread::spawn(move || {
                let mut polls = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let _ = h.snapshot();
                    let _ = h.central_nodes(10 + (r as usize % 3));
                    let _ = h.clusters(3 + (r as usize % 2));
                    polls += 3;
                    std::thread::sleep(std::time::Duration::from_micros(100));
                }
                polls
            }));
        }
        let t0 = std::time::Instant::now();
        let mut batch = Vec::with_capacity(32);
        for i in 0..n_events as u64 {
            batch.push(event(n, i));
            if batch.len() == 32 {
                h.ingest(std::mem::take(&mut batch)).unwrap();
            }
        }
        h.ingest(batch).unwrap();
        h.flush().unwrap();
        let secs = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::Relaxed);
        let served: u64 = readers.into_iter().map(|t| t.join().unwrap()).sum();
        println!(
            "# ingest {n_events} events with {n_readers:>2} readers: {:>8.0} events/s ({served} reads served)",
            n_events as f64 / secs
        );
        record(&mut records, &format!("ingest_{n_events}ev_r{n_readers}"), n_events, secs);
        svc.join();
    }

    write_json(&records);
}
