//! END-TO-END DRIVER — proves all three layers compose on a real
//! workload:
//!
//!   L1 Pallas project-out kernel  ─┐ (lowered together at build time)
//!   L2 JAX build_basis/form_t/rotate ─→ artifacts/*.hlo.txt
//!   L3 Rust coordinator: sparse Δ products + PJRT execution of the
//!      artifacts + native small eigh, over a streaming graph scenario.
//!
//! Workload: a scaled CM-Collab-like collaboration graph (power-law,
//! ~960 nodes) revealed over 10 steps (Scenario 1), K = 64 eigenpairs —
//! the t1024 artifact tier.  For every step we report:
//!   * the XLA-backed G-REST₃ update time,
//!   * the native-Rust G-REST₃ update time (same algorithm, no PJRT),
//!   * a from-scratch Lanczos (`eigs`) time — the paper's baseline,
//!   * eigenvector accuracy ψ of both backends vs the Lanczos reference,
//!   * cross-backend top-eigenvalue agreement.
//!
//! Requires `make artifacts` (skips gracefully with instructions if
//! absent).  Run: cargo run --release --example end_to_end
//!
//! The recorded run lives in EXPERIMENTS.md §End-to-end.

use grest::eval::angle::mean_angle;
use grest::graph::generators;
use grest::graph::scenario::scenario1_from_static;
use grest::linalg::rng::Rng;
use grest::runtime::{ArtifactManifest, XlaPhases};
use grest::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};

fn main() -> anyhow::Result<()> {
    let manifest = match ArtifactManifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts` first");
            return Ok(());
        }
    };

    // ---- workload -------------------------------------------------------
    let n = 960; // fits the t1024 tier with headroom
    let k = 64;
    let t_steps = 10;
    let mut rng = Rng::new(2026);
    let w = generators::power_law_weights(n, 2.5, 4 * n);
    let g = generators::chung_lu(&w, &mut rng);
    let sc = scenario1_from_static("cm-collab-scaled", &g, t_steps);
    let max_s = sc.steps.iter().map(|s| s.delta.s_new).max().unwrap_or(0);
    println!(
        "workload: {} nodes / {} edges revealed {} -> {} over {} steps (max S/step = {})",
        g.n_nodes(),
        g.n_edges(),
        sc.initial.n_rows,
        sc.max_nodes(),
        t_steps,
        max_s
    );

    // ---- trackers -------------------------------------------------------
    let init = init_eigenpairs(&sc.initial, k, 7);
    let phases = XlaPhases::for_problem(manifest, sc.max_nodes(), k, k + max_s)?;
    println!("artifact tier: {:?}\n", phases.tier());
    let mut xla = GRest::with_phases(init.clone(), SubspaceMode::Full, phases, 7);
    let mut native = GRest::new(init, SubspaceMode::Full);

    let (mut t_xla, mut t_nat, mut t_eigs) = (0.0f64, 0.0f64, 0.0f64);
    // steady-state totals exclude step 1, which pays the one-time PJRT
    // compilation of the artifacts
    let (mut ss_xla, mut ss_nat, mut ss_eigs) = (0.0f64, 0.0f64, 0.0f64);
    let (mut psi_xla_sum, mut psi_nat_sum) = (0.0f64, 0.0f64);
    println!("step |    N   S |   xla update | native update |     eigs     | psi_xla  psi_nat | dLambda1");
    for (t, step) in sc.steps.iter().enumerate() {
        let s0 = std::time::Instant::now();
        xla.update(&step.delta)?;
        let d_xla = s0.elapsed();

        let s1 = std::time::Instant::now();
        native.update(&step.delta)?;
        let d_nat = s1.elapsed();

        let s2 = std::time::Instant::now();
        let reference = init_eigenpairs(&step.adjacency, k, 500 + t as u64);
        let d_eigs = s2.elapsed();

        let psi_x = mean_angle(xla.current(), &reference, 32);
        let psi_n = mean_angle(native.current(), &reference, 32);
        let dl1 = (xla.current().values[0] - native.current().values[0]).abs();
        t_xla += d_xla.as_secs_f64();
        t_nat += d_nat.as_secs_f64();
        t_eigs += d_eigs.as_secs_f64();
        if t > 0 {
            ss_xla += d_xla.as_secs_f64();
            ss_nat += d_nat.as_secs_f64();
            ss_eigs += d_eigs.as_secs_f64();
        }
        psi_xla_sum += psi_x;
        psi_nat_sum += psi_n;
        println!(
            "{:>4} | {:>5} {:>3} | {:>10.2?} | {:>11.2?} | {:>10.2?} | {:.4}   {:.4} | {:.2e}",
            t + 1,
            step.adjacency.n_rows,
            step.delta.s_new,
            d_xla,
            d_nat,
            d_eigs,
            psi_x,
            psi_n,
            dl1
        );
    }

    println!("\n================ headline =================");
    println!(
        "total incl. one-time PJRT compile: xla {:.3}s | native {:.3}s | eigs {:.3}s",
        t_xla, t_nat, t_eigs
    );
    println!(
        "steady-state (steps 2..T): xla {:.3}s | native {:.3}s | eigs {:.3}s",
        ss_xla, ss_nat, ss_eigs
    );
    println!(
        "steady-state speedup vs from-scratch eigs: xla {:.1}x, native {:.1}x",
        ss_eigs / ss_xla,
        ss_eigs / ss_nat
    );
    println!(
        "mean psi over run (leading 32): xla {:.4}, native {:.4} (radians)",
        psi_xla_sum / t_steps as f64,
        psi_nat_sum / t_steps as f64
    );
    let ok = ((psi_xla_sum - psi_nat_sum).abs() / t_steps as f64) < 0.02;
    println!(
        "backend agreement: {}",
        if ok { "OK (XLA == native within f32 tolerance)" } else { "MISMATCH" }
    );
    anyhow::ensure!(ok, "XLA and native backends disagree");
    Ok(())
}
