//! Embedding server: the L3 coordinator as a long-running service.
//! A producer thread streams edge events (social-network growth) while
//! concurrent reader threads query snapshots, central nodes, and cluster
//! assignments.  Reports ingest throughput and update/query latencies.
//!
//! ```bash
//! cargo run --release --example embedding_server
//! ```

use grest::coordinator::{BatchPolicy, ServiceConfig, TrackingService};
use grest::graph::generators;
use grest::graph::stream::GraphEvent;
use grest::linalg::rng::Rng;
use grest::linalg::threads::Threads;
use grest::linalg::ServePrecision;
use grest::tracking::TrackerSpec;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(1);
    let g = generators::barabasi_albert(1000, 3, &mut rng);
    println!("seed graph: {} nodes, {} edges", g.n_nodes(), g.n_edges());

    let svc = TrackingService::spawn(ServiceConfig {
        initial: g,
        k: 32,
        policy: BatchPolicy::Either { events: 128, new_nodes: 32, max_age: None },
        seed: 2,
        // the tracker is built on the worker thread — swap in
        // `grest3@xla` here to serve from the PJRT artifacts
        tracker: TrackerSpec::parse("grest-rsvd:l=16,p=16")?,
        // reader-side query kernels (k-means assignment) fan out over
        // this budget; results are identical for any thread count
        threads: Threads::AUTO,
        // flip to ServePrecision::F32 to serve cosine/cluster scans
        // from the f32-storage/f64-accumulate tier
        serve_precision: ServePrecision::F64,
    })?;

    let stop = Arc::new(AtomicBool::new(false));
    // concurrent readers: snapshot pollers + analytics queries — all
    // served lock-free from snapshots, never queued behind ingest
    let mut readers = vec![];
    for r in 0..3u64 {
        let h = svc.handle.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || {
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let snap = h.snapshot();
                assert!(snap.pairs.k() > 0);
                reads += 1;
                if reads % 50 == 0 {
                    match r {
                        0 => {
                            // central nodes arrive as external ids
                            let top = h.central_nodes(10);
                            assert!(top.iter().all(|&id| h.embedding(id).is_some()));
                        }
                        1 => {
                            let _ = h.clusters(4);
                        }
                        _ => {
                            let _ = h.similar_to(reads % 1000, 5);
                        }
                    }
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            reads
        }));
    }

    // producer: stream 20k events
    let n_events = 20_000u64;
    let t0 = std::time::Instant::now();
    let mut batch = Vec::with_capacity(64);
    for i in 0..n_events {
        let ev = if rng.flip(0.9) {
            // preferential-ish growth: attach to low ids more often
            let hub = (rng.below(1000) * rng.below(1000)) / 1000;
            GraphEvent::AddEdge(hub as u64, 1000 + (i / 8))
        } else {
            GraphEvent::RemoveEdge(rng.below(1000) as u64, rng.below(1000) as u64)
        };
        batch.push(ev);
        if batch.len() == 64 {
            svc.handle.ingest(std::mem::take(&mut batch))?;
        }
    }
    svc.handle.ingest(batch)?;
    let final_version = svc.handle.flush()?;
    let elapsed = t0.elapsed();

    stop.store(true, Ordering::Relaxed);
    let total_reads: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();

    let snap = svc.handle.snapshot();
    println!(
        "ingested {} events in {:?} ({:.0} events/s), {} batches applied",
        n_events,
        elapsed,
        n_events as f64 / elapsed.as_secs_f64(),
        final_version
    );
    println!(
        "final embedding: {} nodes x {} eigenpairs, lambda_1 = {:.3}",
        snap.n_nodes,
        snap.pairs.k(),
        snap.pairs.values[0]
    );
    println!("snapshot reads served concurrently: {total_reads}");
    let m = svc.handle.metrics();
    println!(
        "query cache: {} computed / {} cached (hit-rate {:.0}%), snapshot age {:?}",
        m.queries_computed.get(),
        m.queries_cached.get(),
        100.0 * m.query_cache_hit_rate(),
        svc.handle.snapshot_age()
    );
    println!("metrics: {}", m.report());
    svc.join();
    Ok(())
}
