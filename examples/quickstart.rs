//! Quickstart: track the leading eigenpairs of a growing graph with
//! G-REST₃ and compare against a from-scratch Lanczos recompute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use grest::eval::angle::mean_angle;
use grest::graph::generators;
use grest::graph::scenario::scenario1_from_static;
use grest::linalg::rng::Rng;
use grest::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};

fn main() -> anyhow::Result<()> {
    // 1. A synthetic power-law graph (stand-in for a real edge list —
    //    load your own with grest::graph::io::load_graph).
    let mut rng = Rng::new(1);
    let weights = generators::power_law_weights(2000, 2.3, 8000);
    let g = generators::chung_lu(&weights, &mut rng);
    println!("graph: {} nodes, {} edges", g.n_nodes(), g.n_edges());

    // 2. Reveal it over 10 time steps (Scenario 1 of the paper): the
    //    initial half by degree, then batches of new nodes.
    let sc = scenario1_from_static("quickstart", &g, 10);
    println!("initial graph: {} nodes; {} update steps", sc.initial.n_rows, sc.t_steps());

    // 3. Initialize the tracker with the K leading eigenpairs of A(0).
    //    G-REST_RSVD compresses the 100-node-per-step expansion block to
    //    rank 16+16 (paper Sec. 3.5) — the configuration the paper
    //    recommends when many nodes arrive per step.
    let k = 32;
    let init = init_eigenpairs(&sc.initial, k, 7);
    println!("lambda_1..4 = {:?}", &init.values[..4]);
    let mut tracker = GRest::new(init, SubspaceMode::Rsvd { l: 16, p: 16 });

    // 4. Feed updates; measure accuracy against a full recompute.
    for (t, step) in sc.steps.iter().enumerate() {
        let t0 = std::time::Instant::now();
        tracker.update(&step.delta)?;
        let track_time = t0.elapsed();

        let t1 = std::time::Instant::now();
        let reference = init_eigenpairs(&step.adjacency, k, 100 + t as u64);
        let eigs_time = t1.elapsed();

        let psi = mean_angle(tracker.current(), &reference, 3);
        println!(
            "step {}: +{} nodes | G-REST-RSVD {:>9.2?} vs eigs {:>9.2?} ({:>4.1}x faster) | mean psi(top-3) {:.2e}",
            t + 1,
            step.delta.s_new,
            track_time,
            eigs_time,
            eigs_time.as_secs_f64() / track_time.as_secs_f64().max(1e-12),
            psi
        );
    }
    println!(
        "final lambda_1..4 = {:?}",
        &tracker.current().values[..4]
    );
    Ok(())
}
