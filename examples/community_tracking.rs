//! Community tracking on a growing stochastic block model (the paper's
//! Sec. 5.5 workload): nodes join an SBM graph over time; we track the
//! K smallest normalized-Laplacian eigenpairs via the shifted operator
//! Tₙ = 2I − Lₙ (paper Sec. 4.2) and cluster nodes each step, reporting
//! ARI against the ground-truth blocks.
//!
//! ```bash
//! cargo run --release --example community_tracking
//! ```

use grest::graph::scenario::sbm_expansion;
use grest::linalg::rng::Rng;
use grest::tasks::{ari::adjusted_rand_index, clustering};
use grest::tracking::laplacian::{shifted_scenario, Shift};
use grest::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};

fn main() -> anyhow::Result<()> {
    let clusters = 4;
    let mut rng = Rng::new(5);
    let sc = sbm_expansion(1200, clusters, 0.05, 0.004, 1000, 40, 5, &mut rng);
    let labels = sc.labels_per_step.clone().unwrap();
    println!(
        "SBM: {} clusters, growing {} -> {} nodes over {} steps",
        clusters,
        sc.initial.n_rows,
        sc.max_nodes(),
        sc.t_steps()
    );

    // shifted normalized Laplacian stream (leading eigenpairs of Tn are
    // the trailing — cluster-revealing — eigenpairs of Ln)
    let (t0, steps) = shifted_scenario(&sc, Shift::Normalized);
    let init = init_eigenpairs(&t0, clusters, 11);
    let mut tracker = GRest::new(init, SubspaceMode::Full);

    for (t, (delta, t_now)) in steps.iter().enumerate() {
        tracker.update(delta)?;
        let truth = &labels[t + 1];
        let est = clustering::spectral_cluster(&tracker.current().vectors, clusters, 1);
        let ari_tracked = adjusted_rand_index(&est, truth);

        // reference: exact trailing eigenvectors recomputed from scratch
        let refp = init_eigenpairs(t_now, clusters, 200 + t as u64);
        let ref_est = clustering::spectral_cluster(&refp.vectors, clusters, 1);
        let ari_ref = adjusted_rand_index(&ref_est, truth);

        println!(
            "step {}: {} nodes | ARI tracked {:.3} vs exact {:.3} (ratio {:.3})",
            t + 1,
            t_now.n_rows,
            ari_tracked,
            ari_ref,
            ari_tracked / ari_ref.max(1e-9)
        );
    }
    Ok(())
}
