//! Central-node tracking in an evolving social network (the paper's
//! Sec. 5.4 workload as an application): a preferential-attachment
//! "social network" grows live; we maintain subgraph-centrality rankings
//! from the tracked eigenpairs and show how influencer sets shift as the
//! network grows — without ever recomputing the eigendecomposition from
//! scratch.
//!
//! ```bash
//! cargo run --release --example evolving_social_network
//! ```

use grest::graph::datasets;
use grest::graph::scenario::scenario2_from_stream;
use grest::linalg::rng::Rng;
use grest::tasks::centrality;
use grest::tracking::{init_eigenpairs, EigTracker, GRest, SubspaceMode};

fn main() -> anyhow::Result<()> {
    let spec = datasets::by_name("MathOverflow").unwrap();
    let mut rng = Rng::new(9);
    let stream = datasets::build_stream(&spec, &mut rng);
    println!(
        "synthetic {} stream: {} timestamped edges, {} users",
        spec.name,
        stream.len(),
        spec.nodes
    );
    let sc = scenario2_from_stream(&spec.name.to_lowercase(), &stream, 12);

    let k = 32;
    let init = init_eigenpairs(&sc.initial, k, 3);
    let mut tracker = GRest::new(init, SubspaceMode::Rsvd { l: 16, p: 16 });

    let mut prev_top: Vec<usize> = vec![];
    let mut total_update = std::time::Duration::ZERO;
    for (t, step) in sc.steps.iter().enumerate() {
        let t0 = std::time::Instant::now();
        tracker.update(&step.delta)?;
        total_update += t0.elapsed();

        let top = centrality::central_nodes(tracker.current(), 10);
        let churn = if prev_top.is_empty() {
            0
        } else {
            top.iter().filter(|x| !prev_top.contains(x)).count()
        };
        println!(
            "t={:>2}: {:>5} users | top-10 influencers {:?} | churn vs prev: {}",
            t + 1,
            step.adjacency.n_rows,
            &top[..5.min(top.len())],
            churn
        );
        prev_top = top;
    }

    // validate the final ranking against the exact reference
    let final_adj = &sc.steps.last().unwrap().adjacency;
    let reference = init_eigenpairs(final_adj, k, 77);
    let want = centrality::central_nodes(&reference, 100);
    let got = centrality::central_nodes(tracker.current(), 100);
    println!(
        "\nfinal top-100 overlap vs exact eigendecomposition: {:.1}%  (total tracking {:?})",
        100.0 * centrality::overlap(&want, &got),
        total_update
    );
    Ok(())
}
